"""Property tests for the two-tier exchange cost model (DESIGN.md §16).

Four contracts the autotuner's analytic ranking rests on:

  * predicted link bytes are monotone in model size — a bigger model
    never predicts cheaper, so the ranking cannot invert on scale alone;
  * the ICI/DCN tier split is conservative: moving the sharded_ps ring
    across a pod boundary reassigns bytes to the DCN tier but their sum
    equals the untiered total bit-for-bit;
  * ``hierarchical`` at pod_size == 1 *is* ``sharded_ps`` — the DCN leg
    vanishes and every predicted figure collapses to the flat strategy;
  * the DCN-tier wire prediction is exactly the wire's payload accounting
    (per-window encoded all-gather), so predictions across wires scale by
    the wire dtype ratio (plus the quantized formats' scale sidecar).

Hypothesis drives randomized instances where installed; the same
checkers run over a deterministic grid everywhere (pure arithmetic, no
devices), so the contracts are enforced even without hypothesis.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.chunking import build_plan
from repro.core.cost_model import RackTopology, predicted_step_seconds
from repro.core.wire import WireFormat

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# an even-tiered topology: the tier-split property compares seconds too,
# which only sum cleanly when both tiers price a byte identically
EVEN = RackTopology(n_workers_per_rack=8, n_racks=1, bw_worker=10e9,
                    bw_pbox=10e9, bw_core=1e9, bw_ici=1e9, bw_dcn=1e9,
                    lat_ici=1e-6, lat_dcn=1e-6)


def groups_for(n_elems, chunk_bytes, n_shards=8):
    like = {"w": jax.ShapeDtypeStruct((n_elems,), jnp.float32)}
    return build_plan(like, chunk_bytes=chunk_bytes,
                      n_shards=n_shards).groups


def total_bytes(pred):
    return pred["bytes"]["ici"] + pred["bytes"]["dcn"]


# ------------------------------------------------------------- checkers

def check_bytes_monotone(n_elems, extra, chunk_bytes, windows, wire_name):
    """bytes(model + extra) >= bytes(model) for every strategy/wire."""
    wire = (None if wire_name == "identity"
            else WireFormat(name=wire_name, use_pallas=False))
    for strategy, pod, wdcn in (("sharded_ps", 1, None),
                                ("hierarchical", 2, None),
                                ("hierarchical", 2, wire),
                                ("allreduce", 1, None)):
        if strategy == "allreduce" and wire is not None:
            continue
        kw = dict(strategy=strategy, topo=EVEN, windows=windows,
                  n_workers=8, pod_size=pod,
                  wire=None if strategy == "allreduce" else wire,
                  wire_dcn=wdcn)
        small = predicted_step_seconds(groups_for(n_elems, chunk_bytes),
                                       **kw)
        big = predicted_step_seconds(
            groups_for(n_elems + extra, chunk_bytes), **kw)
        assert total_bytes(big) >= total_bytes(small), \
            (strategy, wire_name, n_elems, extra)


def check_tier_split_sums(n_elems, chunk_bytes, windows):
    """sharded_ps across a pod boundary: every ring byte moves to the DCN
    tier, the tier totals sum to the untiered (flat) total exactly."""
    groups = groups_for(n_elems, chunk_bytes)
    kw = dict(strategy="sharded_ps", topo=EVEN, windows=windows,
              n_workers=8)
    flat = predicted_step_seconds(groups, pod_size=1, **kw)
    split = predicted_step_seconds(groups, pod_size=2, **kw)
    assert flat["bytes"]["dcn"] == 0.0
    assert split["bytes"]["ici"] == 0.0
    assert total_bytes(split) == total_bytes(flat)
    # with both tiers priced identically the time is tier-invariant too
    assert split["seconds"] == pytest.approx(flat["seconds"], rel=1e-12)


def check_hierarchical_collapses(n_elems, chunk_bytes, windows, wire_name):
    """pod_size == 1 hierarchical == sharded_ps on every returned figure."""
    wire = (None if wire_name == "identity"
            else WireFormat(name=wire_name, use_pallas=False))
    groups = groups_for(n_elems, chunk_bytes)
    kw = dict(topo=EVEN, windows=windows, n_workers=8, pod_size=1,
              wire=wire)
    hier = predicted_step_seconds(groups, strategy="hierarchical", **kw)
    flat = predicted_step_seconds(groups, strategy="sharded_ps", **kw)
    assert hier == flat


def check_dcn_wire_scales(n_elems, chunk_bytes, windows):
    """The DCN tier carries exactly the wire's payload accounting for the
    per-window encoded all-gather, so two wires' DCN bytes stand in their
    payload ratio — the dtype ratio plus the quantized scale sidecar."""
    groups = groups_for(n_elems, chunk_bytes)
    preds = {}
    for name in ("bf16", "int8"):
        w = WireFormat(name=name, use_pallas=False)
        pred = predicted_step_seconds(
            groups, strategy="hierarchical", topo=EVEN, windows=windows,
            n_workers=8, pod_size=2, wire_dcn=w)
        expected = 0.0
        for g in groups:
            from repro.core.pipeline import effective_windows
            W = effective_windows(g, windows)
            lw = g.shard_len // W
            expected += W * w.payload_bytes(lw, "float32",
                                            g.chunk_elems) * (2 - 1)
        assert pred["bytes"]["dcn"] == expected, name
        preds[name] = pred["bytes"]["dcn"]
    # bf16 is 2 B/elem with no sidecar; int8 is 1 B/elem + f32 scales.
    # Their ratio sits between the pure dtype ratio (2x) and the
    # sidecar-inflated worst case (chunk_elems >= 8 keeps it below 2).
    ratio = preds["bf16"] / preds["int8"]
    assert 1.0 < ratio <= 2.0


# ------------------------------------------------------ deterministic grid

GRID = [(1000, 8 * 1024, 1), (4096, 4 * 1024, 2), (100_000, 32 * 1024, 4),
        (7, 8 * 1024, 1), (215_040, 8 * 1024, 2)]


@pytest.mark.parametrize("n,cb,w", GRID)
@pytest.mark.parametrize("wire", ["identity", "bf16", "int8"])
def test_bytes_monotone(n, cb, w, wire):
    check_bytes_monotone(n, 1 + n // 3, cb, w, wire)


@pytest.mark.parametrize("n,cb,w", GRID)
def test_tier_split_sums_to_untiered(n, cb, w):
    check_tier_split_sums(n, cb, w)


@pytest.mark.parametrize("n,cb,w", GRID)
@pytest.mark.parametrize("wire", ["identity", "int8"])
def test_hierarchical_collapses_to_sharded_ps(n, cb, w, wire):
    check_hierarchical_collapses(n, cb, w, wire)


@pytest.mark.parametrize("n,cb,w", GRID)
def test_dcn_wire_scales_by_dtype_ratio(n, cb, w):
    check_dcn_wire_scales(n, cb, w)


# ------------------------------------------------------------- hypothesis

if HAVE_HYPOTHESIS:
    sizes = st.integers(1, 1 << 18)
    chunks = st.sampled_from([4 * 1024, 8 * 1024, 32 * 1024])
    windows = st.sampled_from([1, 2, 4])

    @settings(max_examples=30, deadline=None)
    @given(sizes, st.integers(1, 1 << 16), chunks, windows,
           st.sampled_from(["identity", "bf16", "int8"]))
    def test_bytes_monotone_hyp(n, extra, cb, w, wire):
        check_bytes_monotone(n, extra, cb, w, wire)

    @settings(max_examples=30, deadline=None)
    @given(sizes, chunks, windows)
    def test_tier_split_sums_hyp(n, cb, w):
        check_tier_split_sums(n, cb, w)

    @settings(max_examples=30, deadline=None)
    @given(sizes, chunks, windows,
           st.sampled_from(["identity", "bf16", "int8"]))
    def test_hierarchical_collapses_hyp(n, cb, w, wire):
        check_hierarchical_collapses(n, cb, w, wire)

    @settings(max_examples=30, deadline=None)
    @given(sizes, chunks, windows)
    def test_dcn_wire_scales_hyp(n, cb, w):
        check_dcn_wire_scales(n, cb, w)
