"""Elastic rack subsystem (DESIGN.md §12).

Fast tests cover the membership state machine (epochs, quorum, masks),
the chaos schedule's determinism and quorum safety, and the rebalance
plan's apply/accounting edges (the move-once / symmetric-difference /
composition contracts are hypothesis-tested in
tests/test_elastic_properties.py).

The 12-device oracle (all-live elastic BITWISE == the PR-4 exchange;
masked-straggler == live-only reference; 8→6→8 resize migrating every
slot bitwise on live regions; cross-rack-size checkpoint restore; the
seeded chaos schedule end to end) runs in a subprocess like
tests/test_client.py.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp
import jax

from repro.core.chunking import build_plan, pack_domains
from repro.core import cost_model
from repro.elastic import (ChaosSchedule, Membership, SOLO_TENANT,
                           plan_rebalance, solo_resize_plan)

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ------------------------------------------------------------- membership

def test_membership_transitions_bump_epoch():
    m = Membership.full(8)
    assert m.epoch == 0 and m.all_live and m.n_live == 8
    m = m.leave(3)
    assert m.epoch == 1 and m.n_live == 7 and not m.all_live
    assert m.live_ranks == (0, 1, 2, 4, 5, 6, 7)
    m = m.mark_slow(5, 4.0)
    assert m.epoch == 2 and m.n_live == 6
    assert m.workers[5].latency == 4.0
    m = m.mark_recovered(5)
    m = m.join(3)
    assert m.epoch == 4 and m.all_live
    # all-live again, but the epoch history is preserved in the signature
    assert m.signature()[0] == 4


def test_membership_mask_matches_live_set():
    m = Membership.full(4).leave(1).mark_slow(2, 2.0)
    assert m.mask().tolist() == [1.0, 0.0, 0.0, 1.0]
    assert m.mask().dtype == np.float32


def test_membership_invalid_transitions():
    m = Membership.full(4)
    with pytest.raises(ValueError, match="already live"):
        m.join(0)
    with pytest.raises(ValueError, match="outside rack"):
        m.leave(7)
    m2 = m.leave(2)
    with pytest.raises(ValueError, match="already left"):
        m2.leave(2)
    with pytest.raises(ValueError, match="join it back"):
        m2.mark_slow(2, 2.0)
    with pytest.raises(ValueError, match=">= 1.0"):
        m.mark_slow(1, 0.5)


def test_membership_quorum_floor():
    m = Membership.full(4, min_live=3)
    m = m.leave(0)
    with pytest.raises(RuntimeError, match="below quorum"):
        m.leave(1)
    m.require_quorum()
    with pytest.raises(RuntimeError, match="below quorum"):
        m.require_quorum(4)


def test_membership_world_validation_and_resize():
    m = Membership.full(8).leave(1)
    with pytest.raises(ValueError, match="resize the rack"):
        m.validate_world(6)
    r = m.resized(6)
    assert r.world == 6 and r.all_live and r.epoch == m.epoch + 1


def test_membership_program_key_ignores_epoch():
    """Compiled steps depend on (world, live set), not the epoch: a
    worker dying, rejoining, and dying again must reuse the first
    compilation (program_key equal), while the full signature still
    tells the two epochs apart (provenance)."""
    m1 = Membership.full(8).leave(3)
    m2 = m1.join(3).leave(3)
    assert m1.epoch != m2.epoch
    assert m1.signature() != m2.signature()
    assert m1.program_key() == m2.program_key()
    assert m1.program_key() != Membership.full(8).leave(4).program_key()


def test_client_step_cache_reuses_recurring_live_sets():
    """PHubClient keys push_pull steps by program key and folds all-live
    to the static entry — churn that revisits a live set never
    retraces."""
    import jax
    import jax.numpy as jnp
    from repro.configs import TrainConfig
    from repro.core import PHubClient
    like = {"w": jax.ShapeDtypeStruct((64, 48), jnp.float32)}
    mesh = jax.make_mesh((1,), ("data",))
    client = PHubClient(TrainConfig(chunk_size_bytes=1024),
                        mesh).register(like)
    grads = jax.tree.map(lambda s: jnp.zeros((1,) + s.shape), like,
                         is_leaf=lambda t: isinstance(t,
                                                      jax.ShapeDtypeStruct))
    params = jax.tree.map(lambda s: jnp.zeros(s.shape), like,
                          is_leaf=lambda t: isinstance(t,
                                                       jax.ShapeDtypeStruct))
    o = client.init_state()
    params, o = client.push_pull(grads, params, o)        # static entry
    client.set_membership(Membership.full(1))             # all-live folds
    params, o = client.push_pull(grads, params, o)
    assert len(client._steps) == 1
    client.set_membership(Membership.full(1).resized(1))  # epoch 1, all
    params, o = client.push_pull(grads, params, o)        # live: reused
    assert len(client._steps) == 1


# ------------------------------------------------------------------ chaos

def test_chaos_schedule_deterministic_and_quorum_safe():
    a = ChaosSchedule.seeded(seed=5, world=8, steps=60, event_every=3)
    b = ChaosSchedule.seeded(seed=5, world=8, steps=60, event_every=3)
    assert a.events == b.events and len(a.events) > 0
    m = Membership.full(8, min_live=5)
    for step in range(60):
        m = a.apply(m, step)        # must never violate quorum
        assert m.n_live >= 5
    f = a.latency_factors(59)
    assert f.shape == (8,) and (f >= 1.0).all()


def test_chaos_apply_is_noop_on_eventless_steps():
    sched = ChaosSchedule.seeded(seed=5, world=8, steps=30, event_every=10)
    m = Membership.full(8)
    assert sched.apply(m, 1) is m           # same object, same epoch


# -------------------------------------------------------- rebalance plans

def _domain(chunks_per_tenant, n_shards, ce=256):
    """A packed domain with the given per-tenant chunk counts (float32,
    chunk_bytes = ce * 4)."""
    plans = {}
    for i, c in enumerate(chunks_per_tenant):
        tree = {"w": jax.ShapeDtypeStruct((c * ce,), jnp.float32)}
        plans[f"t{i}"] = build_plan(tree, chunk_bytes=ce * 4,
                                    n_shards=n_shards)
    return pack_domains(plans, n_shards=n_shards, chunk_bytes=ce * 4)


def test_apply_scatters_tenant_content_exactly():
    old, new = _domain([3, 5], 4), _domain([3, 5], 2)
    plan = plan_rebalance(old, new)
    (key,) = plan.groups
    g = old.groups[key]
    rows = np.arange(g.padded, dtype=np.float32)[None]
    out = plan.apply(key, rows)
    for tenant in ("t0", "t1"):
        a = np.asarray(old.unpack(key, jnp.asarray(rows[0]), tenant))
        b = np.asarray(new.unpack(key, jnp.asarray(out[0]), tenant))
        np.testing.assert_array_equal(a, b)


def test_plan_rejects_mismatched_partitions():
    old = _domain([3, 5], 4)
    with pytest.raises(ValueError, match="tenant sets differ"):
        plan_rebalance(old, _domain([3, 5, 2], 4))
    with pytest.raises(ValueError, match="extents"):
        plan_rebalance(old, _domain([3, 6], 4))


def test_solo_resize_plan_is_identity_on_live():
    plan = solo_resize_plan(np.dtype(np.float32), 256, 1024, 2048, 1536)
    (g,) = plan.groups.values()
    assert g.moves[SOLO_TENANT] == ((0, 0, 0, 1024),)
    rows = np.arange(2048, dtype=np.float32)[None]
    out = plan.apply(str(np.dtype(np.float32)), rows)
    assert out.shape == (1, 1536)
    np.testing.assert_array_equal(out[0, :1024], rows[0, :1024])
    assert (out[0, 1024:] == 0).all()


def test_rebalance_traffic_charges_only_the_delta():
    old, new = _domain([3, 5], 4), _domain([3, 5], 2)
    plan = plan_rebalance(old, new)
    from repro.optim.protocol import SlotSpec
    acct = cost_model.rebalance_traffic(
        plan, (SlotSpec("m"), SlotSpec("wire_ef", "float32")))
    (key,) = plan.groups
    moved = plan.groups[key].moved_elems()
    assert acct["moved_bytes"] == moved * 4 * 3       # param + 2 slots
    assert 0.0 <= acct["moved_fraction"] <= 1.0
    ident = plan_rebalance(old, old)
    acct0 = cost_model.rebalance_traffic(ident, ())
    assert acct0["moved_bytes"] == 0.0                # no-op resize is free


def test_quota_movement_lower_bound():
    from repro.core.partition import quota_movement
    a = [[3, 1], [0, 4]]
    b = [[2, 2], [2, 2]]
    assert quota_movement(a, b) == 1 + 2
    assert quota_movement(a, a) == 0
    # resize: shard counts differ
    assert quota_movement([[4, 4]], [[3, 3, 2]]) == 2


# ----------------------------------------------------------- multi-device

@pytest.mark.slow
@pytest.mark.parametrize("case", ["parity", "straggler", "resize",
                                  "checkpoint", "chaos", "padtail", "dcn"])
def test_multidevice_elastic_oracle(case):
    """The elastic datapath is bitwise the PR-4 exchange when all workers
    are live; masked stragglers equal the live-only reference; 8→6→8
    resizes migrate every slot bitwise on live regions; checkpoints
    restore across rack sizes; a seeded chaos schedule runs end to end;
    adam's k slots hold 0 on dead pad tails through a resize round trip;
    the per-tier int8 DCN wire is bitwise the static client when all-live
    and bitwise ignores dead ranks' pushes when masked — 12 forced host
    devices."""
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "multidevice",
                                      "check_elastic.py"), case],
        capture_output=True, text=True, timeout=1500,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    assert "FAIL" not in proc.stdout
