"""Hypothesis property tests for rebalance delta plans (DESIGN.md §12).

The three contracts the resize machinery rests on:
  * a plan moves each tenant chunk at most once (every chunk appears in
    exactly one run; run sources and destinations each tile the tenant's
    extent exactly once);
  * the delta runs (src != dst) cover exactly the symmetric difference of
    the two placements — an unchanged chunk never costs movement, which
    is the minimal-movement property cost_model.rebalance_traffic
    charges by;
  * plans compose: plan(a→b) ∘ plan(b→c) == plan(a→c) on final placement,
    and applying the composition equals applying the two in sequence.
"""
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.chunking import build_plan, pack_domains  # noqa: E402
from repro.elastic import plan_rebalance  # noqa: E402


def _domain(chunks_per_tenant, n_shards, ce=256):
    """A packed domain with the given per-tenant chunk counts (float32,
    chunk_bytes = ce * 4)."""
    plans = {}
    for i, c in enumerate(chunks_per_tenant):
        tree = {"w": jax.ShapeDtypeStruct((c * ce,), jnp.float32)}
        plans[f"t{i}"] = build_plan(tree, chunk_bytes=ce * 4,
                                    n_shards=n_shards)
    return pack_domains(plans, n_shards=n_shards, chunk_bytes=ce * 4)


def _placement_map(domain, key):
    """{tenant: {tenant_chunk: packed_chunk}} ground truth from the
    domain's own offset tables."""
    g = domain.groups[key]
    ce = g.chunk_elems
    out = {}
    for s in g.slots:
        m = {}
        for toff, poff, ln in s.runs:
            for k in range(ln // ce):
                m[(toff + k * ce) // ce] = (poff + k * ce) // ce
        out[s.tenant] = m
    return out


chunk_counts = st.lists(st.integers(1, 23), min_size=1, max_size=4)


@settings(max_examples=40, deadline=None)
@given(chunk_counts, st.integers(2, 9), st.integers(2, 9))
def test_plan_moves_each_chunk_at_most_once(counts, s_old, s_new):
    """Every tenant chunk appears in exactly one run; run sources and
    destinations each tile the tenant's extent exactly once."""
    old, new = _domain(counts, s_old), _domain(counts, s_new)
    plan = plan_rebalance(old, new)
    for key, g in plan.groups.items():
        ce = g.chunk_elems
        for tenant, runs in g.moves.items():
            toffs, srcs, dsts = set(), set(), set()
            ext = 0
            for toff, src, dst, ln in runs:
                assert ln % ce == 0 and ln > 0
                for k in range(0, ln, ce):
                    for acc, v in ((toffs, toff + k), (srcs, src + k),
                                   (dsts, dst + k)):
                        assert v not in acc          # at most once
                        acc.add(v)
                ext += ln
            slot = old.groups[key].slot(tenant)
            assert ext == slot.padded                # exactly once


@settings(max_examples=40, deadline=None)
@given(chunk_counts, st.integers(2, 9), st.integers(2, 9))
def test_plan_delta_is_exactly_the_symmetric_difference(counts, s_old,
                                                        s_new):
    """Chunks in delta runs (src != dst) == chunks whose placement differs
    between the partitions; everything else stays put."""
    old, new = _domain(counts, s_old), _domain(counts, s_new)
    plan = plan_rebalance(old, new)
    for key in plan.groups:
        pm_old = _placement_map(old, key)
        pm_new = _placement_map(new, key)
        placements = plan.chunk_placements(key)
        for tenant, pairs in placements.items():
            changed_ref = {c for c in pm_old[tenant]
                           if pm_old[tenant][c] != pm_new[tenant][c]}
            moved = set()
            for i, (src, dst) in enumerate(pairs):
                assert pm_old[tenant][i] == src
                assert pm_new[tenant][i] == dst
                if src != dst:
                    moved.add(i)
            assert moved == changed_ref


@settings(max_examples=25, deadline=None)
@given(chunk_counts, st.integers(2, 9), st.integers(2, 9),
       st.integers(2, 9))
def test_plans_compose(counts, s_a, s_b, s_c):
    """plan(a→b) ∘ plan(b→c) == plan(a→c) on final placement, and
    applying the composed plan equals applying the two in sequence."""
    da, db, dc = (_domain(counts, s) for s in (s_a, s_b, s_c))
    p_ab, p_bc = plan_rebalance(da, db), plan_rebalance(db, dc)
    p_ac = plan_rebalance(da, dc)
    comp = p_ab.compose(p_bc)
    for key in p_ac.groups:
        assert comp.chunk_placements(key) == p_ac.chunk_placements(key)
        rng = np.random.default_rng(0)
        rows = rng.normal(size=(1, p_ac.groups[key].old_padded)
                          ).astype(np.float32)
        via = p_bc.apply(key, p_ab.apply(key, rows))
        direct = p_ac.apply(key, rows)
        np.testing.assert_array_equal(via, direct)
