"""Exchange strategy semantics.

The full distributed equivalence check (every strategy == single-device
oracle on a (data=4, model=2) mesh, three arch families) needs 8 fake
devices, so it runs in a subprocess — the in-process jax runtime here stays
single-device for the other tests.
"""
import os
import subprocess
import sys

import pytest

from repro.core.exchange import ExchangeContext, STRATEGIES

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_strategies_registry():
    assert set(STRATEGIES) == {"allreduce", "sharded_ps", "centralized_ps",
                               "hierarchical", "fsdp_stream"}


def test_exchange_context_shards():
    ctx = ExchangeContext(data_axes=("pod", "data"),
                          axis_sizes={"pod": 2, "data": 16, "model": 16})
    assert ctx.n_workers == 32
    assert ctx.n_shards("sharded_ps") == 32       # flat across pods
    assert ctx.n_shards("hierarchical") == 16     # in-pod shards only
    assert ctx.n_shards("allreduce") == 1
    assert ctx.state_len("sharded_ps", 3200) == 100
    assert ctx.state_len("allreduce", 3200) == 3200


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["allreduce", "sharded_ps",
                                      "centralized_ps", "hierarchical",
                                      "fsdp_stream"])
def test_multidevice_equivalence(strategy):
    """Each strategy's train step == data-parallel oracle (subprocess with
    XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "multidevice",
                                      "check_engine.py"), strategy],
        capture_output=True, text=True, timeout=1200,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    assert "FAIL" not in proc.stdout


@pytest.mark.slow
@pytest.mark.parametrize("variant", ["dp_over_model", "microbatch"])
def test_multidevice_variants(variant):
    """Beyond-paper schemes (dp-over-model sharding, gradient accumulation)
    must also match the data-parallel oracle."""
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "multidevice",
                                      "check_engine.py"), variant],
        capture_output=True, text=True, timeout=1200,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    assert "FAIL" not in proc.stdout
