"""HLO collective parser unit tests (roofline input integrity)."""
import textwrap

from repro.utils.hlo import (parse_collectives, parse_concat_sizes,
                             parse_donated_params, parse_host_callbacks,
                             summarize_collectives, CollectiveStats)

SAMPLE = textwrap.dedent("""\
    %ar = f32[16,1024]{1,0} all-reduce(f32[16,1024]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
    %ag = bf16[512,64]{1,0} all-gather(bf16[32,64]{1,0} %y), replica_groups=[2,16]<=[32], dimensions={0}
    %rs = f32[64]{0} reduce-scatter(f32[1024]{0} %z), replica_groups={{0,256},{1,257}}, dimensions={0}, to_apply=%add
    %cp = bf16[8,8]{1,0} collective-permute(bf16[8,8]{1,0} %w), source_target_pairs={{0,1},{1,0}}
    %nothing = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
""")


def test_parse_kinds_and_sizes():
    stats = parse_collectives(SAMPLE, pod_stride=256)
    kinds = {s.kind: s for s in stats}
    assert set(kinds) == {"all-reduce", "all-gather", "reduce-scatter",
                          "collective-permute"}
    assert kinds["all-reduce"].payload_bytes == 16 * 1024 * 4
    assert kinds["all-reduce"].group_size == 4
    assert not kinds["all-reduce"].spans_pod
    assert kinds["all-gather"].payload_bytes == 512 * 64 * 2
    assert kinds["all-gather"].group_size == 16
    assert kinds["reduce-scatter"].spans_pod       # {0,256} crosses pods
    assert kinds["reduce-scatter"].group_size == 2


def test_link_bytes_conventions():
    ar = CollectiveStats("all-reduce", 1000, 4, False)
    assert ar.link_bytes() == 2 * 1000 * 3 / 4
    ag = CollectiveStats("all-gather", 1000, 4, False)
    assert ag.link_bytes() == 1000 * 3 / 4
    rs = CollectiveStats("reduce-scatter", 100, 4, False)
    assert rs.link_bytes() == 300
    cp = CollectiveStats("collective-permute", 64, 1, True)
    assert cp.link_bytes() == 64


def test_summary_tiers():
    stats = parse_collectives(SAMPLE, pod_stride=256)
    s = summarize_collectives(stats)
    assert s["dcn_bytes"] > 0 and s["ici_bytes"] > 0
    assert set(s["by_kind"]) == {"all-reduce", "all-gather", "reduce-scatter",
                                 "collective-permute"}


def test_parse_concat_sizes():
    """Concat extraction feeding the flat-residency zero-copy assertion
    (DESIGN.md §8)."""
    txt = textwrap.dedent("""\
        %c1 = f32[1024]{0} concatenate(f32[512]{0} %a, f32[512]{0} %b), dimensions={0}
        %c2 = bf16[4,8]{1,0} concatenate(bf16[4,4]{1,0} %x, bf16[4,4]{1,0} %y), dimensions={1}
        %n = f32[4]{0} add(f32[4]{0} %p, f32[4]{0} %q)
    """)
    sizes = parse_concat_sizes(txt)
    assert sorted(sizes) == [4 * 8 * 2, 1024 * 4]
    assert parse_concat_sizes("%n = f32[4]{0} add(f32[4]{0} %a)") == []


def test_iota_groups_transpose():
    txt = ("%ag2 = f32[4]{0} all-gather(f32[2]{0} %v), "
           "replica_groups=[256,2]<=[2,256]T(1,0), dimensions={0}\n")
    (s,) = parse_collectives(txt, pod_stride=256)
    # groups pair device i with i+256 -> spans pods
    assert s.group_size == 2 and s.spans_pod


def test_async_start_done_counts_once():
    """``X-start`` tuples echo the operand; only the output half is
    payload, and the matching ``-done`` carries nothing."""
    txt = textwrap.dedent("""\
        %ags = (f32[32,64]{1,0}, f32[512,64]{1,0}) all-gather-start(f32[32,64]{1,0} %y), replica_groups={{0,1}}, dimensions={0}
        %agd = f32[512,64]{1,0} all-gather-done((f32[32,64]{1,0}, f32[512,64]{1,0}) %ags)
        %cps = (u32[128]{0}, u32[128]{0}, u32[], u32[]) collective-permute-start(u32[128]{0} %p), source_target_pairs={{0,1},{1,0}}
        %cpd = u32[128]{0} collective-permute-done((u32[128]{0}, u32[128]{0}, u32[], u32[]) %cps)
    """)
    kinds = {s.kind: s for s in parse_collectives(txt)}
    assert set(kinds) == {"all-gather", "collective-permute"}
    assert kinds["all-gather"].count == 1
    assert kinds["all-gather"].payload_bytes == 512 * 64 * 4
    assert kinds["collective-permute"].count == 1
    assert kinds["collective-permute"].payload_bytes == 128 * 4


def test_all_reduce_start_no_halving():
    """all-reduce-start results carry each payload once (no operand
    echo): a variadic start tuple counts every element."""
    txt = ("%ars = (f32[8]{0}, s32[4]{0}) all-reduce-start("
           "f32[8]{0} %a, s32[4]{0} %b), replica_groups={{0,1,2,3}}, "
           "to_apply=%add\n")
    (s,) = parse_collectives(txt)
    assert s.kind == "all-reduce"
    assert s.payload_bytes == 8 * 4 + 4 * 4


def test_variadic_tuple_collective():
    txt = ("%var = (f32[16]{0}, bf16[32]{0}, s8[8]{0}) all-reduce("
           "f32[16]{0} %a, bf16[32]{0} %b, s8[8]{0} %c), "
           "replica_groups={{0,1}}, to_apply=%add\n")
    (s,) = parse_collectives(txt)
    assert s.payload_bytes == 16 * 4 + 32 * 2 + 8
    assert dict(s.by_dtype) == {"f32": 64, "bf16": 64, "s8": 8}


def test_subbyte_dtypes():
    """s4/u4 payloads account in bits: 8 nibbles = 4 bytes."""
    txt = textwrap.dedent("""\
        %q = s4[8,16]{1,0} all-gather(s4[1,16]{1,0} %a), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
        %r = u4[64]{0} collective-permute(u4[64]{0} %b), source_target_pairs={{0,1}}
    """)
    kinds = {s.kind: s for s in parse_collectives(txt)}
    assert kinds["all-gather"].payload_bytes == 8 * 16 // 2
    assert kinds["collective-permute"].payload_bytes == 32
    assert dict(kinds["all-gather"].by_dtype) == {"s4": 64}


def test_parse_donated_params():
    txt = ("HloModule jit_step, input_output_alias={ {0}: (0, {}, "
           "may-alias), {2}: (3, {}, must-alias) }, "
           "entry_computation_layout={(f32[4]{0})->f32[4]{0}}\n")
    assert parse_donated_params(txt) == {0, 3}
    assert parse_donated_params("HloModule jit_step\n") == set()


def test_parse_host_callbacks():
    txt = textwrap.dedent("""\
        %cc = f32[4]{0} custom-call(f32[4]{0} %x), custom_call_target="xla_ffi_python_cpu_callback"
        %ok = f32[4]{0} custom-call(f32[4]{0} %y), custom_call_target="TopK"
    """)
    hits = parse_host_callbacks(txt)
    assert hits == ["xla_ffi_python_cpu_callback"]
