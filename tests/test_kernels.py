"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.agg_opt.ops import fused_agg_opt, fused_multi_agg_opt
from repro.kernels.agg_opt.ref import agg_opt_ref
from repro.kernels.swa_attn.ops import swa_attention
from repro.kernels.swa_attn.ref import swa_attention_ref
from repro.kernels.rwkv_scan.kernel import rwkv_scan_kernel
from repro.kernels.rwkv_scan.ops import rwkv_scan
from repro.kernels.rwkv_scan.ref import rwkv_scan_ref
from repro.kernels.decode_attn.ops import decode_attention
from repro.kernels.decode_attn.ref import decode_attention_ref

KEY = jax.random.PRNGKey(0)


def rnd(i, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, i), shape) *
            scale).astype(dtype)


# ------------------------------------------------------------------ agg_opt

@pytest.mark.parametrize("n", [128, 8192, 20000, 65536 + 7])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_agg_opt_sweep(n, dtype):
    p, g, m = rnd(1, (n,), dtype), rnd(2, (n,), dtype), rnd(3, (n,), dtype)
    p2, m2 = fused_agg_opt(p, g, m, lr=0.05, momentum=0.9, chunk_elems=8192)
    pr, mr = agg_opt_ref(p, g, m, lr=0.05, momentum=0.9)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(p2, np.float32),
                               np.asarray(pr, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(m2, np.float32),
                               np.asarray(mr, np.float32), atol=tol)


@pytest.mark.parametrize("W", [1, 2, 8])
def test_fused_multi_agg_opt_workers(W):
    n = 5000
    p, m = rnd(4, (n,)), rnd(5, (n,))
    g = rnd(6, (W, n))
    p2, m2 = fused_multi_agg_opt(p, g, m, lr=0.1, momentum=0.9,
                                 chunk_elems=1024)
    pr, mr = agg_opt_ref(p, g, m, lr=0.1, momentum=0.9, n_workers=W)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(pr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(mr), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(100, 9000), st.sampled_from([256, 1024, 8192]),
       st.floats(0.0, 0.99))
def test_fused_agg_opt_property(n, ce, momentum):
    p, g, m = rnd(7, (n,)), rnd(8, (n,)), rnd(9, (n,))
    p2, m2 = fused_agg_opt(p, g, m, lr=0.01, momentum=momentum,
                           chunk_elems=ce)
    pr, mr = agg_opt_ref(p, g, m, lr=0.01, momentum=momentum)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(pr), atol=1e-5)


# ----------------------------------------------------------------- swa_attn

@pytest.mark.parametrize("T,nh,kv,hd,window,bq", [
    (128, 4, 2, 64, 0, 64),
    (128, 4, 2, 64, 32, 32),
    (128, 2, 2, 120, 48, 64),      # danube head_dim (lane padding)
    (64, 8, 1, 32, 0, 32),         # MQA-style
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_attention_sweep(T, nh, kv, hd, window, bq, dtype):
    B = 2
    q = rnd(10, (B, T, nh, hd), dtype)
    k = rnd(11, (B, T, kv, hd), dtype)
    v = rnd(12, (B, T, kv, hd), dtype)
    o = swa_attention(q, k, v, window=window, bq=bq, bk=bq)
    ref = swa_attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                            jnp.moveaxis(v, 1, 2), window=window)
    ref = jnp.moveaxis(ref, 2, 1)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


# ---------------------------------------------------------------- rwkv_scan

@pytest.mark.parametrize("T,hd,ct", [(64, 64, 16), (128, 64, 64),
                                     (96, 32, 32)])
def test_rwkv_scan_kernel_sweep(T, hd, ct):
    BH = 3
    r, k, v = (rnd(i, (BH, T, hd), scale=0.5) for i in (20, 21, 22))
    w = jnp.exp(-jnp.exp(rnd(23, (BH, T, hd), scale=0.5) - 2.0))
    u = rnd(24, (BH, 1, hd), scale=0.5)
    y, s = rwkv_scan_kernel(r, k, v, w, u, ct=ct, interpret=True)
    yr, sr = rwkv_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=2e-4)


def test_rwkv_scan_strong_decay_stability():
    """Adversarially strong decay (w -> 0.37^64 cumulative) stays finite."""
    BH, T, hd = 1, 64, 32
    r, k, v = (rnd(i, (BH, T, hd), scale=0.5) for i in (25, 26, 27))
    w = jnp.full((BH, T, hd), jnp.exp(-1.0))       # aggressive decay
    u = rnd(28, (BH, 1, hd))
    y, s = rwkv_scan_kernel(r, k, v, w, u, ct=32, interpret=True)
    yr, sr = rwkv_scan_ref(r, k, v, w, u)
    assert np.isfinite(np.asarray(y)).all()
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-2)


def test_rwkv_scan_model_layout_wrapper():
    B, T, H, hd = 2, 64, 3, 32
    r, k, v = (rnd(i, (B, T, H, hd), scale=0.5) for i in (30, 31, 32))
    w = jnp.exp(-jnp.exp(rnd(33, (B, T, H, hd), scale=0.3) - 2.0))
    u = rnd(34, (H, hd), scale=0.5)
    state = jnp.zeros((B, H, hd, hd))
    y, s = rwkv_scan(r, k, v, w, u, state, ct=16, interpret=True)
    from repro.models.rwkv import rwkv_recurrence
    yr, sr = rwkv_recurrence(r, k, v, w, u, state)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4)


# -------------------------------------------------------------- decode_attn

@pytest.mark.parametrize("S,nh,kv,hd,window", [
    (256, 4, 2, 64, 0),
    (300, 4, 2, 64, 100),          # non-multiple S, windowed
    (512, 8, 8, 128, 0),           # MHA
    (1024, 5, 5, 64, 256),         # musicgen/hymba-ish head counts
])
def test_decode_attention_sweep(S, nh, kv, hd, window):
    B = 2
    q = rnd(40, (B, 1, nh, hd))
    k = rnd(41, (B, S, kv, hd))
    v = rnd(42, (B, S, kv, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    fill = int(S * 0.8)
    pos = jnp.where(pos < fill, pos, -1)
    qp = jnp.full((B,), fill, jnp.int32)
    o = decode_attention(q, k, v, pos, qp, window=window, bs=128)
    ref = decode_attention_ref(q[:, 0].reshape(B, kv, nh // kv, hd), k, v,
                               pos, qp.reshape(B, 1), window=window)
    np.testing.assert_allclose(np.asarray(o).reshape(B, kv, nh // kv, hd),
                               np.asarray(ref), atol=3e-5)


def test_decode_attention_ring_rotation():
    B, S, nh, kv, hd = 1, 128, 2, 1, 32
    q = rnd(50, (B, 1, nh, hd))
    k = rnd(51, (B, S, kv, hd))
    v = rnd(52, (B, S, kv, hd))
    pos = jnp.arange(S, dtype=jnp.int32)[None]
    qp = jnp.full((B,), S - 1, jnp.int32)
    base = decode_attention(q, k, v, pos, qp, window=0, bs=64)
    r = 37
    rot = lambda x: jnp.roll(x, r, axis=1)
    rotated = decode_attention(q, rot(k), rot(v), rot(pos), qp, window=0,
                               bs=64)
    np.testing.assert_allclose(np.asarray(base), np.asarray(rotated),
                               atol=1e-5)


# -------------------------------------------------------------------- quant

def test_quant_roundtrip_matches_ref():
    from repro.kernels.quant.ops import dequantize_int8, quantize_int8
    from repro.kernels.quant.ref import dequantize_int8_ref, quantize_int8_ref
    n, ce = 4096, 256
    x = rnd(10, (n,), scale=3.0)
    q, s = quantize_int8(x, chunk_elems=ce)
    qr, sr = quantize_int8_ref(x, ce)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))
    np.testing.assert_array_equal(
        np.asarray(dequantize_int8(q, s, chunk_elems=ce)),
        np.asarray(dequantize_int8_ref(qr, sr, ce)))


def test_quant_rejects_misaligned_layout():
    from repro.kernels.quant.ops import quantize_int8
    with pytest.raises(ValueError, match="lane-aligned"):
        quantize_int8(rnd(11, (300,)), chunk_elems=100)


def test_quant_zero_chunk_is_exact():
    from repro.kernels.quant.ops import dequantize_int8, quantize_int8
    x = jnp.zeros((256,), jnp.float32)
    q, s = quantize_int8(x, chunk_elems=128)
    assert float(np.abs(np.asarray(s)).min()) > 0      # safe divide
    np.testing.assert_array_equal(
        np.asarray(dequantize_int8(q, s, chunk_elems=128)), np.zeros(256))


@pytest.mark.parametrize("W_inv", [1.0, 0.125])
def test_fused_dequant_agg_opt_matches_ref(W_inv):
    from repro.kernels.agg_opt.ops import fused_dequant_agg_opt
    from repro.kernels.agg_opt.ref import dequant_agg_opt_ref
    from repro.kernels.quant.ref import quantize_int8_ref
    n, ce = 2048, 256
    p, m, gown = rnd(12, (n,)), rnd(13, (n,)), rnd(14, (n,), scale=2.0)
    q, s = quantize_int8_ref(rnd(15, (n,), scale=4.0), ce)
    p2, m2 = fused_dequant_agg_opt(p, q, s, gown, m, lr=0.05, momentum=0.9,
                                   inv_n=W_inv, chunk_elems=ce)
    pr, mr = dequant_agg_opt_ref(p, q, s, gown, m, lr=0.05, momentum=0.9,
                                 inv_n=W_inv, chunk_elems=ce)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(pr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(mr), atol=1e-6)
