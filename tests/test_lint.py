"""rack-lint (DESIGN.md §15): rules, seeded fixtures, diagnostics, and
the single-device slices of the R2 retrace scenarios.

The full 8-device matrix sweep lives in ``python -m repro.launch.lint``
(CI's lint job); here every rule is exercised at unit level and every
seeded known-bad fixture must be flagged by exactly its rule.
"""
import json

import jax
import numpy as np
import pytest

from repro.analysis import (Diagnostic, LintReport, artifact_from_engine,
                            check_donation, check_hygiene,
                            check_retrace_co, check_retrace_sanity,
                            check_schedule, check_traffic, fixtures,
                            lint_artifact)
from repro.analysis.fixtures import (_artifact, _group,
                                     _hlo_sharded_identity, _with_aliases)
from repro.configs import ARCHS, TrainConfig
from repro.configs.base import InputShape, reduced
from repro.core import PHubEngine, chunking
from repro.core.api import PHubConnectionManager
from repro.data import SyntheticTokens
from repro.data.synthetic import make_batch_specs
from repro.resilience import SanityConfig

CFG = reduced(ARCHS["llama3.2-1b"])
SHAPE = InputShape(name="lint-t", seq_len=16, global_batch=4, kind="train")


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


# ------------------------------------------------------------ diagnostics

def test_diagnostic_serialization_and_severity_gate():
    d = Diagnostic("R1", "error", "cell/a", "boom", {"got": 2, "want": 1})
    round_trip = d.to_dict()
    assert round_trip["rule"] == "R1"
    assert round_trip["evidence"] == {"got": 2, "want": 1}
    assert "cell/a" in str(d) and "boom" in str(d)
    with pytest.raises(ValueError):
        Diagnostic("R1", "fatal", "cell/a", "bad severity")


def test_lint_report_counts_and_save(tmp_path):
    rep = LintReport()
    rep.add(Diagnostic("R1", "error", "c", "x"))
    rep.extend([Diagnostic("R5", "warning", "c", "y"),
                Diagnostic("R5", "info", "c", "z")])
    rep.record_cell({"tag": "c", "status": "ok"})
    assert rep.count("error") == 1 and len(rep.errors) == 1
    assert rep.by_rule()["R5"]["warning"] == 1
    path = rep.save(str(tmp_path / "sub" / "report.json"))
    loaded = json.load(open(path))
    assert loaded["summary"]["error"] == 1
    assert loaded["summary"]["cells"] == 1
    assert len(loaded["diagnostics"]) == 3


# --------------------------------------------------- seeded fixtures (R*)

@pytest.mark.parametrize("fixture_fn", [
    fixtures.inflated_traffic, fixtures.dropped_donation,
    fixtures.reordered_schedule, fixtures.racing_schedule,
    fixtures.pad_aggregated_live, fixtures.dropped_chunk_coverage,
    fixtures.smuggled_f64, fixtures.raw_wire_leak, fixtures.host_callback,
    fixtures.flat_concat,
], ids=lambda f: f.__name__)
def test_fixture_flagged_by_its_rule_and_clean_twin_passes(fixture_fn):
    f = fixture_fn()
    assert f.flagged, (f"{f.name}: seeded {f.rule} defect went unflagged: "
                       f"{[str(d) for d in f.bad]}")
    assert not f.false_positive, (
        f"{f.name}: clean twin flagged: {[str(d) for d in f.clean]}")
    assert f.ok


def test_all_fixtures_enumerates_every_rule():
    rules = {f.rule for f in fixtures.all_fixtures()}
    assert rules == {"R1", "R3", "R4", "R5"}


# ------------------------------------------------------------ R1 traffic

def test_traffic_unmodeled_strategy_is_info_not_error():
    g = _group({"w": 4096})
    art = _artifact(g, _hlo_sharded_identity(g), tag="t/unmodeled")
    art.strategy = "centralized_ps"
    diags = check_traffic(art)
    assert [d.severity for d in diags] == ["info"]


def test_traffic_tolerance_absorbs_scalar_noise():
    # a 4-byte scalar pmean riding the step must stay inside abs_tol
    g = _group({"w": 4096})
    noisy = _hlo_sharded_identity(g, extra_ops=(
        "  %pm = f32[1]{0} all-reduce(f32[1]{0} %upd), channel_id=9, "
        "replica_groups={{0,1,2,3}}, to_apply=%add\n"))
    art = _artifact(g, noisy, tag="t/scalar-noise")
    assert not [d for d in check_traffic(art) if d.severity == "error"]


# ----------------------------------------------------------- R3 donation

def test_donation_counts_and_missing_alias():
    g = _group({"w": 4096})
    base = _hlo_sharded_identity(g)
    good = _artifact(g, _with_aliases(base, (0, 1)), donated_count=2,
                     tag="t/donation")
    assert not [d for d in check_donation(good) if d.severity == "error"]
    bad = _artifact(g, base, donated_count=2, tag="t/donation-none")
    errs = [d for d in check_donation(bad) if d.severity == "error"]
    assert errs and errs[0].rule == "R3"
    assert errs[0].evidence["missing_params"] == [0, 1]


# ----------------------------------------------------------- R4 schedule

def test_schedule_clean_windows_have_no_diags():
    g = _group({"a": 512, "b": 3584})
    assert check_schedule("t/sched", g, 2) == []


def test_schedule_flags_duplicate_and_dropped_chunks():
    g = _group({"w": 4096})
    sets = [list(s) for s in chunking.window_chunks(g, 2)]
    sets[1][0] = sets[0][0]
    diags = check_schedule("t/sched-cov", g, 2,
                           window_chunk_sets=tuple(tuple(s) for s in sets))
    errs = [d for d in diags if d.severity == "error"]
    assert errs and all(d.rule == "R4" for d in errs)


def test_schedule_flags_understated_readiness():
    g = _group({"a": 512, "b": 3584})
    order, ready = chunking.chunk_ready_schedule(g, 2)
    diags = check_schedule("t/sched-race", g, 2, order=order,
                           ready=tuple(max(0.0, r - 0.25) for r in ready))
    assert any(d.rule == "R4" and d.severity == "error" for d in diags)


# ------------------------------------------------------------ R5 hygiene

def test_hygiene_wire_rule_toggle():
    # the raw f32 leak past an int8 encoder is an error with the wire
    # rule on, and deliberately tolerated when the caller disables it
    # (model-sharded meshes legitimately all-gather raw activations)
    g = _group({"w": 4096})
    rg = "{{0,1,2,3}}"
    leak = (f"ENTRY %main.1 (p0: f32[{g.shard_len}]) -> "
            f"f32[{g.padded}] {{\n"
            f"  %p0 = f32[{g.shard_len}]{{0}} parameter(0)\n"
            f"  %ag = f32[{g.padded}]{{0}} all-gather("
            f"f32[{g.shard_len}]{{0}} %p0), channel_id=1, "
            f"replica_groups={rg}, dimensions={{0}}\n"
            f"  ROOT %o = f32[{g.padded}]{{0}} copy(f32[{g.padded}]{{0}} "
            f"%ag)\n}}\n")
    bad = _artifact(g, leak, wire_format="int8", tag="t/wire-toggle")
    assert any(d.severity == "error" for d in check_hygiene(bad))
    assert not check_hygiene(bad, wire_rule=False)


def test_hygiene_flags_f64_and_host_callback():
    g = _group({"w": 4096})
    wide = (f"  %c = f64[{g.shard_len}]{{0}} convert("
            f"f32[{g.shard_len}]{{0}} %rs)\n"
            f"  %cb = f32[1]{{0}} custom-call(f32[1]{{0}} %c), "
            f"custom_call_target=\"xla_ffi_python_cpu_callback\"\n")
    art = _artifact(g, _hlo_sharded_identity(g, extra_ops=wide),
                    tag="t/hygiene-both")
    msgs = [d.message for d in check_hygiene(art) if d.severity == "error"]
    assert len(msgs) == 2


# ----------------------------------- live artifacts + retrace (1 device)

def test_single_device_zero_artifact_lints_clean():
    eng = PHubEngine(cfg=CFG, tc=TrainConfig(), mesh=_mesh())
    art = artifact_from_engine(eng, "t/solo-zero", kind="zero")
    assert art.donated_count == len(
        jax.tree.leaves((eng.params_shapes, eng.opt_state_shapes())))
    assert not [d for d in lint_artifact(art) if d.severity == "error"]


def _batch_for(eng, shapes):
    data = SyntheticTokens(CFG, SHAPE.global_batch, SHAPE.seq_len, seed=0)
    sh = eng.batch_shardings(shapes)
    return {k: jax.device_put(v, sh[k]) for k, v in data.batch_at(0).items()}


def test_retrace_sanity_threshold_rides_traced_input():
    eng = PHubEngine(cfg=CFG, tc=TrainConfig(), mesh=_mesh())
    shapes = make_batch_specs(CFG, SHAPE)
    p, o = eng.init_state(jax.random.PRNGKey(0))
    diags = check_retrace_sanity(eng, shapes, p, o, _batch_for(eng, shapes),
                                 SanityConfig(), tag="t/sanity")
    assert diags == [], [str(d) for d in diags]


def test_retrace_co_detach_reattach_reuses_step_cache():
    mgr = PHubConnectionManager()
    cfg_b = reduced(ARCHS["llama3.2-1b"], d_model=128)
    mesh = _mesh()
    ha = mgr.create_service("a", CFG, TrainConfig(), mesh)
    hb = mgr.create_service("b", cfg_b, TrainConfig(), mesh)
    pa, _ = mgr.init_service(ha, jax.random.PRNGKey(1))
    pb, _ = mgr.init_service(hb, jax.random.PRNGKey(2))
    batches = {
        "a": SyntheticTokens(CFG, 4, 16, seed=3).batch_at(0),
        "b": SyntheticTokens(cfg_b, 4, 16, seed=4).batch_at(0),
    }
    diags = check_retrace_co(mgr, [ha, hb], {"a": pa, "b": pb}, batches,
                             tag="t/co")
    assert diags == [], [str(d) for d in diags]


def test_replicated_shardings_are_canonical_rank_free():
    # the retrace guarantee hinges on init-state shardings matching jit
    # outputs: fully-replicated leaves carry P() (never P(None, ...)),
    # sharded specs carry no trailing None
    from jax.sharding import PartitionSpec as P
    eng = PHubEngine(cfg=CFG, tc=TrainConfig(), mesh=_mesh())
    for s in jax.tree.leaves(eng.param_shardings()):
        assert s.spec == P()
    for s in jax.tree.leaves(eng.opt_state_shardings()):
        assert len(s.spec) == 0 or s.spec[-1] is not None
