"""Chunked CE == full CE; synthetic pipeline determinism + learnability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.data import SyntheticTokens
from repro.models.loss import chunked_cross_entropy


@pytest.mark.parametrize("T,chunk", [(64, 64), (64, 16), (60, 16), (5, 64)])
def test_chunked_ce_matches_full(T, chunk):
    key = jax.random.PRNGKey(0)
    B, d, V = 3, 16, 50
    x = jax.random.normal(key, (B, T, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, V))
    labels = jax.random.randint(jax.random.fold_in(key, 2), (B, T), 0, V)
    got = chunked_cross_entropy(x, w, labels, chunk=chunk)
    logits = x @ w
    logp = jax.nn.log_softmax(logits)
    want = -jnp.take_along_axis(logp, labels[..., None], -1).mean()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_chunked_ce_masks_negative_labels():
    key = jax.random.PRNGKey(1)
    B, T, d, V = 2, 8, 4, 11
    x = jax.random.normal(key, (B, T, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, V))
    labels = jnp.arange(B * T, dtype=jnp.int32).reshape(B, T) % V
    masked = labels.at[:, :4].set(-1)
    got = chunked_cross_entropy(x, w, masked, chunk=4)
    want = chunked_cross_entropy(x[:, 4:], w, labels[:, 4:], chunk=4)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_chunked_ce_grad_finite():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (2, 32, 8))
    w = jax.random.normal(jax.random.fold_in(key, 1), (8, 20))
    labels = jnp.zeros((2, 32), jnp.int32)
    g = jax.grad(lambda w_: chunked_cross_entropy(x, w_, labels, chunk=8))(w)
    assert np.isfinite(np.asarray(g)).all()


def test_synthetic_determinism_and_range():
    cfg = reduced(ARCHS["llama3.2-1b"])
    d1 = SyntheticTokens(cfg, 4, 32, seed=5).batch_at(7)
    d2 = SyntheticTokens(cfg, 4, 32, seed=5).batch_at(7)
    np.testing.assert_array_equal(d1["tokens"], d2["tokens"])
    assert d1["tokens"].min() >= 0
    assert d1["tokens"].max() < cfg.vocab_size
    assert d1["tokens"].shape == (4, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(d1["labels"][:, :-1], d1["tokens"][:, 1:])
    d3 = SyntheticTokens(cfg, 4, 32, seed=5).batch_at(8)
    assert (d3["tokens"] != d1["tokens"]).any()


def test_synthetic_task_is_learnable_in_principle():
    """Sequences are mostly affine progressions: given (start, stride) the
    next token is determined 98% of the time — so loss can go well below
    uniform."""
    cfg = reduced(ARCHS["llama3.2-1b"])
    b = SyntheticTokens(cfg, 64, 64, seed=0).batch_at(0)
    tok = b["tokens"].astype(np.int64)
    stride = (tok[:, 1] - tok[:, 0]) % cfg.vocab_size
    pred = (tok[:, 1:-1] + stride[:, None]) % cfg.vocab_size
    acc = (pred == tok[:, 2:]).mean()
    assert acc > 0.9
