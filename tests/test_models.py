"""Per-arch smoke tests (deliverable f): reduced same-family variants run a
forward AND a PHub train step on CPU; output shapes + no NaNs asserted."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, TrainConfig, reduced
from repro.core import PHubEngine
from repro.data import SyntheticTokens
from repro.models import (init, forward, prefill, lm_head_weight,
                          chunked_cross_entropy, layer_windows,
                          cache_capacity)

B, T = 2, 32


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def _batch_inputs(cfg):
    tok = jnp.arange(B * T, dtype=jnp.int32).reshape(B, T) % cfg.vocab_size
    extra = (jnp.ones((B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
             if cfg.frontend else None)
    return tok, extra


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_forward_smoke(arch_id):
    cfg = reduced(ARCHS[arch_id])
    params = init(cfg, jax.random.PRNGKey(0))
    tok, extra = _batch_inputs(cfg)
    out = forward(cfg, params, tok, extra_embeds=extra, remat=False)
    t_total = T + (cfg.frontend_tokens if cfg.frontend else 0)
    assert out["x"].shape == (B, t_total, cfg.d_model)
    assert not bool(jnp.isnan(out["x"]).any())
    loss = chunked_cross_entropy(out["x"][:, -T:], lm_head_weight(cfg, params),
                                 tok, chunk=16)
    assert jnp.isfinite(loss)


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_train_step_smoke(arch_id, mesh11):
    cfg = reduced(ARCHS[arch_id])
    eng = PHubEngine(cfg=cfg, tc=TrainConfig(loss_chunk=16), mesh=mesh11)
    params, opt = eng.init_state(jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, B, T, seed=0)
    batch = data.device_batch(0)
    shapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
              for k, v in batch.items()}
    if cfg.frontend:
        batch["extra_embeds"] = jnp.ones(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        shapes["extra_embeds"] = jax.ShapeDtypeStruct(
            batch["extra_embeds"].shape, batch["extra_embeds"].dtype)
    step = eng.make_train_step(shapes)
    import numpy as np
    before = jax.tree.map(lambda a: np.asarray(a, np.float32), params)
    p1, o1, metrics = step(params, opt, batch)    # donates params/opt
    assert jnp.isfinite(metrics["loss"])
    # params actually moved, and no NaNs anywhere
    moved = jax.tree.map(
        lambda a, b: bool((a != np.asarray(b, np.float32)).any()), before, p1)
    assert any(jax.tree.leaves(moved))
    assert not any(bool(jnp.isnan(l).any()) for l in jax.tree.leaves(p1)
                   if jnp.issubdtype(l.dtype, jnp.floating))


@pytest.mark.parametrize("arch_id", ["llama3.2-1b", "h2o-danube-3-4b",
                                     "rwkv6-3b", "hymba-1.5b",
                                     "musicgen-medium"])
def test_prefill_decode_consistency(arch_id):
    """Decoding token T after a prefill of length T must match the full
    forward over T+1 tokens (exercises the ring cache end-to-end)."""
    cfg = reduced(ARCHS[arch_id])
    params = init(cfg, jax.random.PRNGKey(1))
    tok = (jnp.arange(B * (T + 1), dtype=jnp.int32).reshape(B, T + 1)
           % cfg.vocab_size)
    full = forward(cfg, params, tok, remat=False)
    pf = prefill(cfg, params, tok[:, :T], remat=False,
                 cache_dtype=jnp.float32, max_new_tokens=1)
    out = forward(cfg, params, tok[:, T:], cache=pf["cache"], remat=False)
    want = full["x"][:, T]
    got = out["x"][:, 0]
    err = float(jnp.abs(want.astype(jnp.float32)
                        - got.astype(jnp.float32)).max())
    scale = float(jnp.abs(want).max()) + 1e-6
    assert err / scale < 0.08, f"relative err {err/scale:.4f}"


def test_windows_hymba():
    cfg = ARCHS["hymba-1.5b"]
    w = layer_windows(cfg)
    assert w[0] == 0 and w[16] == 0 and w[-1] == 0      # global layers
    assert (w[1:16] == cfg.sliding_window).all()
    assert cache_capacity(cfg, 524_288) == 32_768       # StreamingLLM cap
    assert cache_capacity(ARCHS["h2o-danube-3-4b"], 524_288) == 4096
    assert cache_capacity(ARCHS["llama3.2-1b"], 32_768) == 32_768


def test_sliding_window_limits_attention():
    """With window w, logits at position t must not depend on tokens < t-w."""
    import dataclasses
    cfg = dataclasses.replace(reduced(ARCHS["h2o-danube-3-4b"]),
                              sliding_window=8)
    params = init(cfg, jax.random.PRNGKey(2))
    tok = jnp.ones((1, 24), jnp.int32)
    tok2 = tok.at[0, 2].set(5)                # outside window of position 23
    x1 = forward(cfg, params, tok, remat=False)["x"][:, -1]
    x2 = forward(cfg, params, tok2, remat=False)["x"][:, -1]
    # single layer of attention: last position differs only through tokens in
    # (15, 23]; with 2 layers receptive field is 2w, so use position 2 < 23-16
    err = float(jnp.abs(x1.astype(jnp.float32) - x2.astype(jnp.float32)).max())
    assert err < 1e-3, f"token outside receptive field leaked: {err}"
