"""Optimizer math: Nesterov matches manual recurrence; Adam bias correction;
the fused kernel's vector update equals the pytree update."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (nesterov_init, nesterov_update, adam_init,
                         adam_update, make_optimizer)
from repro.configs import TrainConfig
from repro.kernels.agg_opt.ops import fused_agg_opt


def test_nesterov_two_steps_manual():
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st = nesterov_init(p)
    p1, st = nesterov_update(p, g, st, lr=0.1, momentum=0.9)
    # m1 = g;  p1 = p - lr (g + 0.9 g) = p - 0.19 g
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               [1 - 0.095, -2 - 0.095], atol=1e-6)
    p2, st = nesterov_update(p1, g, st, lr=0.1, momentum=0.9)
    # m2 = 0.9*0.5 + 0.5 = 0.95; step = 0.1*(0.5 + 0.855)
    np.testing.assert_allclose(np.asarray(st["m"]["w"]), [0.95, 0.95],
                               atol=1e-6)


def test_weight_decay_applied():
    p = {"w": jnp.array([2.0])}
    g = {"w": jnp.array([0.0])}
    p1, _ = nesterov_update(p, g, nesterov_init(p), lr=0.1, momentum=0.0,
                            weight_decay=0.1)
    np.testing.assert_allclose(np.asarray(p1["w"]), [2.0 - 0.1 * 0.2],
                               atol=1e-6)


def test_adam_first_step_is_lr_sized():
    p = {"w": jnp.array([0.0])}
    g = {"w": jnp.array([3.0])}
    p1, st = adam_update(p, g, adam_init(p), lr=0.01)
    np.testing.assert_allclose(np.asarray(p1["w"]), [-0.01], rtol=1e-4)
    assert int(st["t"]) == 1


def test_factory():
    for name in ("nesterov", "sgd", "adam"):
        init, upd = make_optimizer(TrainConfig(optimizer=name, lr=0.1))
        p = {"w": jnp.ones((4,))}
        st = init(p)
        p1, _ = upd(p, {"w": jnp.ones((4,))}, st)
        assert p1["w"].shape == (4,)


def test_fused_kernel_equals_tree_update():
    n = 3000
    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    st = nesterov_init({"w": p})
    p_tree, st2 = nesterov_update({"w": p}, {"w": g}, st, lr=0.03,
                                  momentum=0.9)
    p_vec, m_vec = fused_agg_opt(p, g, jnp.zeros((n,)), lr=0.03, momentum=0.9,
                                 chunk_elems=1024)
    np.testing.assert_allclose(np.asarray(p_tree["w"]), np.asarray(p_vec),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(st2["m"]["w"]), np.asarray(m_vec),
                               atol=1e-6)
