"""Optimizer math: the sharded-optimizer protocol rules (Nesterov manual
recurrence, Adam bias correction, SGD), their tree-level wrappers, and the
fused Pallas kernels against the protocol bodies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamOptimizer, NesterovOptimizer, SGDOptimizer,
                         RuleBinding, adam_init, adam_update,
                         make_combined_update, make_optimizer,
                         make_sharded_optimizer, nesterov_init,
                         nesterov_update, tuple_update, union_slots)
from repro.configs import TrainConfig
from repro.kernels.agg_opt.ops import (fused_adam_opt, fused_agg_opt,
                                       fused_sgd_opt)


def test_nesterov_two_steps_manual():
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st = nesterov_init(p)
    p1, st = nesterov_update(p, g, st, lr=0.1, momentum=0.9)
    # m1 = g;  p1 = p - lr (g + 0.9 g) = p - 0.19 g
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               [1 - 0.095, -2 - 0.095], atol=1e-6)
    p2, st = nesterov_update(p1, g, st, lr=0.1, momentum=0.9)
    # m2 = 0.9*0.5 + 0.5 = 0.95; step = 0.1*(0.5 + 0.855)
    np.testing.assert_allclose(np.asarray(st["m"]["w"]), [0.95, 0.95],
                               atol=1e-6)


def test_weight_decay_applied():
    p = {"w": jnp.array([2.0])}
    g = {"w": jnp.array([0.0])}
    p1, _ = nesterov_update(p, g, nesterov_init(p), lr=0.1, momentum=0.0,
                            weight_decay=0.1)
    np.testing.assert_allclose(np.asarray(p1["w"]), [2.0 - 0.1 * 0.2],
                               atol=1e-6)


def test_adam_first_step_is_lr_sized():
    p = {"w": jnp.array([0.0])}
    g = {"w": jnp.array([3.0])}
    p1, st = adam_update(p, g, adam_init(p), lr=0.01)
    np.testing.assert_allclose(np.asarray(p1["w"]), [-0.01], rtol=1e-4)
    # bias correction rides per-position k slots holding 1 - b^t directly
    # (they shard/window/migrate like every other slot), float32 always
    np.testing.assert_allclose(np.asarray(st["k1"]["w"]), [0.1], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(st["k2"]["w"]), [0.001], rtol=1e-4)
    assert st["k1"]["w"].dtype == jnp.float32


def test_factory():
    for name in ("nesterov", "sgd", "adam"):
        init, upd = make_optimizer(TrainConfig(optimizer=name, lr=0.1))
        p = {"w": jnp.ones((4,))}
        st = init(p)
        p1, _ = upd(p, {"w": jnp.ones((4,))}, st)
        assert p1["w"].shape == (4,)
    with pytest.raises(ValueError, match="unknown optimizer"):
        make_optimizer(TrainConfig(optimizer="lion"))


def test_rule_identity_and_slot_union():
    """Equal statics -> one rule; slot union shares same-named slots."""
    a = make_sharded_optimizer(TrainConfig(optimizer="adam"))
    b = make_sharded_optimizer(TrainConfig(optimizer="adam"))
    assert a == b and hash(a) == hash(b)
    c = make_sharded_optimizer(TrainConfig(optimizer="adam", adam_b1=0.8))
    assert a != c                       # different statics = distinct rule
    n = make_sharded_optimizer(TrainConfig(optimizer="nesterov"))
    names = [s.name for s in union_slots([n, a])]
    assert names == ["m", "v", "k1", "k2"]   # nesterov's m shared with adam's


def test_combined_update_masks_select_owner_rule():
    """Mixed nesterov+adam combined rule: each position gets bitwise its
    owner rule's output; foreign slots stay untouched."""
    nes, adam = NesterovOptimizer(), AdamOptimizer()
    specs = union_slots([nes, adam])
    idx = {s.name: i for i, s in enumerate(specs)}
    upd = make_combined_update([
        RuleBinding(opt=nes, slot_idx=(idx["m"],), coefs=(0.1, 0.9),
                    mask_aux=0),
        RuleBinding(opt=adam,
                    slot_idx=(idx["m"], idx["v"], idx["k1"], idx["k2"]),
                    coefs=(0.01,), mask_aux=1),
    ])
    n = 8
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.normal(size=n).astype(np.float32))
    g = jnp.asarray(rng.normal(size=n).astype(np.float32))
    slots = tuple(jnp.zeros(n, jnp.float32) for _ in specs)
    mask_n = jnp.asarray(([1.0, 0.0] * 4), jnp.float32)
    mask_a = 1.0 - mask_n
    p2, s2 = upd(p, g, slots, mask_n, mask_a)
    pn, (mn,) = nes.update(p, g, (slots[idx["m"]],), (0.1, 0.9))
    pa, (ma, va, k1a, k2a) = adam.update(
        p, g, (slots[idx["m"]], slots[idx["v"]], slots[idx["k1"]],
               slots[idx["k2"]]), (0.01,))
    sel = np.asarray(mask_n) != 0
    np.testing.assert_array_equal(np.asarray(p2)[sel], np.asarray(pn)[sel])
    np.testing.assert_array_equal(np.asarray(p2)[~sel], np.asarray(pa)[~sel])
    np.testing.assert_array_equal(np.asarray(s2[idx["m"]])[sel],
                                  np.asarray(mn)[sel])
    np.testing.assert_array_equal(np.asarray(s2[idx["v"]])[sel], 0.0)
    np.testing.assert_array_equal(np.asarray(s2[idx["v"]])[~sel],
                                  np.asarray(va)[~sel])


def test_fused_kernel_equals_tree_update():
    n = 3000
    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    st = nesterov_init({"w": p})
    p_tree, st2 = nesterov_update({"w": p}, {"w": g}, st, lr=0.03,
                                  momentum=0.9)
    p_vec, m_vec = fused_agg_opt(p, g, jnp.zeros((n,)), lr=0.03, momentum=0.9,
                                 chunk_elems=1024)
    np.testing.assert_allclose(np.asarray(p_tree["w"]), np.asarray(p_vec),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(st2["m"]["w"]), np.asarray(m_vec),
                               atol=1e-6)


def test_fused_sgd_kernel_equals_protocol():
    n = 3000
    key = jax.random.PRNGKey(2)
    p = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    p_ref, () = tuple_update(SGDOptimizer(), (0.05,))(p, g, ())
    p_vec = fused_sgd_opt(p, g, lr=0.05, chunk_elems=1024)
    np.testing.assert_allclose(np.asarray(p_ref), np.asarray(p_vec),
                               atol=1e-6)


def test_fused_adam_kernel_equals_protocol():
    n = 3000
    key = jax.random.PRNGKey(3)
    p = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    m = jax.random.normal(jax.random.fold_in(key, 2), (n,)) * 0.1
    v = jnp.abs(jax.random.normal(jax.random.fold_in(key, 3), (n,))) * 0.01
    k1 = jnp.full((n,), 1 - 0.9 ** 3)
    k2 = jnp.full((n,), 1 - 0.999 ** 3)
    opt = AdamOptimizer()
    p_ref, (m_ref, v_ref, k1_ref, k2_ref) = tuple_update(opt, (0.01,))(
        p, g, (m, v, k1, k2))
    p_vec, m_vec, v_vec, k1_vec, k2_vec = fused_adam_opt(
        p, g, m, v, k1, k2, lr=0.01, chunk_elems=1024)
    np.testing.assert_allclose(np.asarray(p_ref), np.asarray(p_vec),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(m_ref), np.asarray(m_vec),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(v_ref), np.asarray(v_vec),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(k1_ref), np.asarray(k1_vec),
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(k2_ref), np.asarray(k2_vec),
                               atol=1e-7)
