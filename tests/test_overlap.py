"""Backward-overlap exchange: engine gating + the multidevice bitwise
oracle (DESIGN.md §14)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, TrainConfig, reduced
from repro.core import PHubEngine
from repro.core.client import PHubClient

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_overlap_requires_pipelined_strategy():
    cfg = reduced(ARCHS["llama3.2-1b"], d_model=64)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tc = TrainConfig(strategy="allreduce", overlap_backward=True)
    with pytest.raises(ValueError, match="overlap_backward"):
        PHubEngine(cfg=cfg, tc=tc, mesh=mesh)
    with pytest.raises(ValueError, match="overlap_backward"):
        PHubClient(tc, jax.make_mesh((1,), ("data",)))


def test_overlap_requires_single_model_shard():
    """The readiness hook only supports the mo == 1 store layout (the
    engine gate enforces the same invariant mesh-side)."""
    from repro.core.chunking import build_plan, build_store_layout
    tree = {"w": jnp.zeros((64, 4), jnp.float32)}
    plan = build_plan(tree, chunk_bytes=64, n_shards=2)
    layout = build_store_layout(plan, {p: 0 for g in plan.groups
                                       for p in g.paths}, 2)
    with pytest.raises(ValueError, match="single model"):
        layout.window_flats(tree, {"float32": 2})


def test_overlap_changes_exchange_signature():
    """overlap_backward restructures the compiled step, so it must key
    the engine's step cache."""
    a = TrainConfig(strategy="sharded_ps")
    b = TrainConfig(strategy="sharded_ps", overlap_backward=True)
    assert a.exchange_signature() != b.exchange_signature()


def test_overlap_single_device_step_runs():
    """1-worker smoke: the chunk-ready path compiles and trains (the
    bitwise claim lives in the multidevice oracle below)."""
    cfg = reduced(ARCHS["llama3.2-1b"], d_model=64)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tc = TrainConfig(strategy="sharded_ps", lr=1e-3, loss_chunk=32,
                     pipeline_windows=2, chunk_size_bytes=1024,
                     overlap_backward=True)
    eng = PHubEngine(cfg=cfg, tc=tc, mesh=mesh)
    params, opt = eng.init_state(jax.random.PRNGKey(0))
    from repro.data import SyntheticTokens
    data = SyntheticTokens(cfg, 4, 32, seed=0)
    batch = data.batch_at(0)
    shapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
              for k, v in batch.items()}
    step = eng.make_train_step(shapes)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    params, opt, m = step(params, opt, batch)
    assert jnp.isfinite(m["loss"])


# ----------------------------------------------------------- multi-device

@pytest.mark.slow
@pytest.mark.parametrize("case", ["nesterov", "sgd", "adam", "flat",
                                  "client", "elastic"])
def test_multidevice_overlap_oracle(case):
    """Chunk-ready overlapped schedule == post-backward schedule, bitwise,
    across optimizer x strategy x windows x wire, flat residency, the
    standalone client, and k-of-n masking — 8 forced host devices."""
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "multidevice",
                                      "check_overlap.py"), case],
        capture_output=True, text=True, timeout=1500,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    assert "FAIL" not in proc.stdout
