"""Chunk-ready dispatch schedule properties (DESIGN.md §14).

The backward-overlap exchange dispatches window rings in readiness order
(reverse of the layer-order window schedule).  Whatever the leaf layout,
that dispatch must remain a *permutation* of the layer-order schedule:
every chunk of the padded domain dispatched exactly once, no chunk lost
to a reordering bug.  Plus the deterministic seam check: the per-window
buffers assembled by FlatParamStore.window_flats must be exactly the
strided split (split_windows) of the monolithic flat cotangent.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.chunking import (build_plan, build_store_layout,  # noqa: E402
                                 chunk_ready_schedule, split_windows,
                                 window_chunks)
from repro.core.pipeline import effective_windows  # noqa: E402


def _tree_strategy():
    shapes = st.lists(
        st.tuples(st.integers(1, 5), st.integers(1, 17)), min_size=1,
        max_size=6)
    dtypes = st.sampled_from(["float32", "bfloat16"])
    return st.tuples(shapes, st.lists(dtypes, min_size=1, max_size=6))


@settings(max_examples=40, deadline=None)
@given(_tree_strategy(), st.integers(1, 4), st.sampled_from([64, 256]),
       st.integers(1, 6))
def test_dispatch_is_permutation_of_layer_order(tree_spec, n_shards,
                                                chunk_bytes, requested):
    """Chunk-ready dispatch order x window chunk sets = the layer-order
    schedule's chunks, each exactly once."""
    shapes, dtypes = tree_spec
    tree = {f"k{i}": jnp.zeros(s, dtype=dtypes[i % len(dtypes)])
            for i, s in enumerate(shapes)}
    plan = build_plan(tree, chunk_bytes=chunk_bytes, n_shards=n_shards)
    for g in plan.groups:
        W = effective_windows(g, requested)
        wins = window_chunks(g, W)
        order, ready = chunk_ready_schedule(g, W)
        n_chunks = g.n_shards * g.chunks_per_shard
        # layer order already tiles the chunk domain exactly once
        assert sorted(c for w in wins for c in w) == list(range(n_chunks))
        # dispatch order is a permutation of the window indices...
        assert sorted(order) == list(range(W))
        # ...so the dispatched chunk stream covers every chunk exactly once
        dispatched = [c for w in order for c in wins[w]]
        assert sorted(dispatched) == list(range(n_chunks))
        # readiness fractions are sane and the dispatch respects them:
        # a window never launches before an earlier-ready one
        assert all(0.0 <= r <= 1.0 for r in ready)
        assert len(ready) == W
        assert list(order) == sorted(range(W), key=lambda w: (ready[w], w))
        # backward closes leaves in reverse concat order, so readiness is
        # non-increasing in window index; with strictly decreasing
        # readiness (no leaf spanning a window boundary ties it) the
        # dispatch is exactly the reverse of the layer-order schedule
        assert all(ready[w] >= ready[w + 1] for w in range(W - 1))
        if all(ready[w] > ready[w + 1] for w in range(W - 1)):
            assert list(order) == list(reversed(range(W)))


@settings(max_examples=25, deadline=None)
@given(_tree_strategy(), st.integers(1, 4), st.integers(1, 4))
def test_window_flats_match_split_of_monolithic_flat(tree_spec, n_shards,
                                                     requested):
    """The readiness hook's per-window buffers are exactly the strided
    split of grad_from_tree's monolithic flat cotangent — same values,
    different dependency structure."""
    shapes, dtypes = tree_spec
    rng = np.random.default_rng(0)
    tree = {f"k{i}": jnp.asarray(rng.normal(size=s).astype("float32"),
                                 dtype=dtypes[i % len(dtypes)])
            for i, s in enumerate(shapes)}
    plan = build_plan(tree, chunk_bytes=64, n_shards=n_shards)
    layout = build_store_layout(plan, {p: None for g in plan.groups
                                       for p in g.paths}, 1)
    wins = {str(g.dtype): effective_windows(g, requested)
            for g in plan.groups}
    per_window = layout.window_flats(tree, wins)
    mono = layout.grad_from_tree(tree)
    for g in plan.groups:
        key = str(g.dtype)
        expect = split_windows(mono[key].reshape(-1), g, wins[key])
        got = per_window[key]
        assert len(got) == wins[key]
        for a, b in zip(got, expect):
            np.testing.assert_array_equal(np.asarray(a).reshape(-1),
                                          np.asarray(b).reshape(-1))


def test_window_chunks_rejects_non_tiling_windows():
    tree = {"w": jnp.zeros((64,), jnp.float32)}
    plan = build_plan(tree, chunk_bytes=64, n_shards=2)
    (g,) = plan.groups
    bad = g.chunks_per_shard + 1
    with pytest.raises(ValueError):
        window_chunks(g, bad)
    with pytest.raises(ValueError):
        chunk_ready_schedule(g, g.shard_len + 1)
