"""LPT 4/3-approximation set partition (§3.2.4) property tests."""
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.partition import lpt_partition, bin_loads, makespan_ratio


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(1, 10_000), min_size=1, max_size=200),
       st.integers(1, 16))
def test_lpt_is_complete_and_bounded(costs, n_bins):
    assign = lpt_partition(costs, n_bins)
    assert len(assign) == len(costs)
    assert all(0 <= b < n_bins for b in assign)
    loads = bin_loads(costs, assign, n_bins)
    assert sum(loads) == sum(costs)
    # Graham's bound: makespan <= (4/3 - 1/(3m)) * OPT, and OPT >= max(
    #   mean load, max item)
    opt_lb = max(sum(costs) / n_bins, max(costs))
    assert max(loads) <= (4 / 3) * opt_lb + 1e-9


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 12), st.integers(2, 8))
def test_uniform_chunks_balance_perfectly(n_per_bin, n_bins):
    """PHub's case: equal 32KB chunks — LPT gives perfect balance when the
    count divides evenly (the TPU flattened-concat datapath relies on this:
    see DESIGN.md §7)."""
    costs = [32 * 1024] * (n_per_bin * n_bins)
    assign = lpt_partition(costs, n_bins)
    assert makespan_ratio(costs, assign, n_bins) == 1.0


def test_pathological_keys_still_balanced():
    """One huge FC-layer key next to many small conv keys (AlexNet-like)."""
    costs = [150_000_000] + [300_000] * 60
    assign = lpt_partition(costs, 8)
    ratio = makespan_ratio(costs, assign, 8)
    # the giant key dominates: LPT puts it alone; ratio is limited by the
    # max-item lower bound, not by poor packing
    loads = bin_loads(costs, assign, 8)
    assert max(loads) == 150_000_000
