"""Pipelined chunk-window exchange + flat parameter residency (DESIGN.md §8).

Single-device tests cover the window math, the FlatParamStore offset table,
single-worker pipeline parity, and the zero-copy HLO property; the
multi-device parity checks (pipelined == monolithic on 8 fake devices for
sharded_ps and hierarchical, flat == tree) run in a subprocess like
tests/test_exchange.py.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, TrainConfig, reduced
from repro.core.chunking import build_plan, build_store_layout, flatten_groups
from repro.core.exchange import ExchangeContext, exchange_group
from repro.core.pipeline import (PIPELINED_STRATEGIES, effective_windows,
                                 pipelined_exchange, run_exchange)

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ------------------------------------------------------------- window math

def test_effective_windows_respects_chunk_boundaries():
    tree = {"w": jnp.zeros((4096,), jnp.float32)}   # 16 KB
    plan = build_plan(tree, chunk_bytes=1024, n_shards=2)
    (g,) = plan.groups
    assert g.chunks_per_shard == 8
    assert effective_windows(g, 1) == 1
    assert effective_windows(g, 4) == 4
    assert effective_windows(g, 5) == 4      # largest divisor of 8 below 5
    assert effective_windows(g, 100) == 8    # clamped to chunks_per_shard
    assert effective_windows(g, 0) == 1


def test_pipelined_strategies_registry():
    assert set(PIPELINED_STRATEGIES) == {"sharded_ps", "hierarchical"}


# --------------------------------------------- single-worker pipeline parity

def _upd(lr=0.1, mu=0.9):
    def f(p, g, slots):
        (m,) = slots
        m2 = mu * m + g
        return p - lr * (g + mu * m2), (m2,)
    return f


def _bind_data_axis(fn):
    """Run ``fn`` inside a 1-device shard_map so collective axis names
    resolve (exchange schedules always execute in a manual region)."""
    from jax.sharding import PartitionSpec as P
    from repro.utils import compat
    mesh = jax.make_mesh((1,), ("data",))
    return compat.shard_map(fn, mesh=mesh, in_specs=(), out_specs=P(),
                            axis_names={"data"}, check_vma=False)()


@pytest.mark.parametrize("windows", [2, 4, 8])
def test_single_worker_windows_match_monolithic(windows):
    """With one worker the ring degenerates to identity and the windowed
    schedule must reproduce the monolithic update exactly."""
    ctx = ExchangeContext(data_axes=("data",), axis_sizes={"data": 1})
    rng = np.random.default_rng(0)
    n = 1024
    g = jnp.asarray(rng.normal(size=n).astype(np.float32))
    p = jnp.asarray(rng.normal(size=n).astype(np.float32))
    m = jnp.zeros(n, jnp.float32)
    rank = jnp.zeros((), jnp.int32)

    def both():
        p_ref, (m_ref,) = exchange_group("sharded_ps", ctx, g, p, (m,),
                                         _upd(), rank)
        p_win, (m_win,) = pipelined_exchange("sharded_ps", ctx, g, p, (m,),
                                             _upd(), rank, windows)
        return p_ref, m_ref, p_win, m_win

    p_ref, m_ref, p_win, m_win = _bind_data_axis(both)
    np.testing.assert_allclose(np.asarray(p_win), np.asarray(p_ref),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(m_win), np.asarray(m_ref),
                               rtol=1e-5, atol=1e-6)


def test_run_exchange_dispatch():
    """run_exchange falls back to the monolithic schedule for strategies
    without a shard dimension and for windows=1."""
    ctx = ExchangeContext(data_axes=("data",), axis_sizes={"data": 1})
    tree = {"w": jnp.zeros((1024,), jnp.float32)}
    plan = build_plan(tree, chunk_bytes=512, n_shards=1)
    (grp,) = plan.groups
    g = jnp.ones(grp.padded)
    p = jnp.zeros(grp.padded)
    m = jnp.zeros(grp.padded)
    rank = jnp.zeros((), jnp.int32)
    for strategy in ("allreduce", "sharded_ps"):
        def both():
            p2, _ = run_exchange(strategy, ctx, g, p, (m,), _upd(), rank,
                                 grp, 4)
            p1, _ = exchange_group(strategy, ctx, g, p, (m,), _upd(), rank)
            return p2, p1
        p2, p1 = _bind_data_axis(both)
        np.testing.assert_allclose(np.asarray(p2), np.asarray(p1),
                                   rtol=1e-6)


# ------------------------------------------------------------ FlatParamStore

def test_store_roundtrip_mo1():
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": jnp.arange(6.0).reshape(2, 3) + 100}
    plan = build_plan(tree, chunk_bytes=64, n_shards=2)
    layout = build_store_layout(plan, {"['a']": None, "['b']": None}, 1)
    store = layout.from_tree(tree)
    (g,) = plan.groups
    assert store["float32"].shape == (1, g.padded)
    back = layout.to_tree(store, tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))
    # store row == flatten_groups vector (same chunk domain)
    flats = flatten_groups(plan, tree)
    np.testing.assert_array_equal(np.asarray(store["float32"][0]),
                                  np.asarray(flats["float32"]))


def test_store_roundtrip_model_sharded_rows():
    """mo=2: model-sharded leaves split across rows; replicated leaves are
    read from row 0."""
    tree = {"w": jnp.arange(16.0).reshape(2, 8),     # sharded on dim 1
            "r": jnp.arange(4.0)}                    # replicated
    local = {"w": jax.ShapeDtypeStruct((2, 4), jnp.float32),
             "r": jax.ShapeDtypeStruct((4,), jnp.float32)}
    plan = build_plan(local, chunk_bytes=32, n_shards=1)
    layout = build_store_layout(plan, {"['w']": 1, "['r']": None}, 2)
    store = layout.from_tree(tree)
    assert store["float32"].shape[0] == 2
    back = layout.to_tree(store, tree)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))
    np.testing.assert_array_equal(np.asarray(back["r"]), np.asarray(tree["r"]))
    # offsets are static python ints
    for offs in layout.offsets.values():
        assert all(isinstance(o, int) for o in offs)


def test_store_gradient_is_flat():
    """d(loss)/d(store) lands directly in the flat chunk domain."""
    tree = {"a": jnp.ones((3, 4)), "b": jnp.ones((5,))}
    plan = build_plan(tree, chunk_bytes=64, n_shards=1)
    layout = build_store_layout(plan, {"['a']": None, "['b']": None}, 1)
    store = layout.from_tree(tree)

    def loss(s):
        t = layout.to_tree(s, tree)
        return (t["a"] ** 2).sum() + (3 * t["b"]).sum()

    gstore = jax.grad(loss)(store)
    (g,) = plan.groups
    assert gstore["float32"].shape == (1, g.padded)
    flat = np.asarray(gstore["float32"][0])
    np.testing.assert_allclose(flat[:12], 2.0)       # d(a^2)=2a, a=1
    np.testing.assert_allclose(flat[12:17], 3.0)
    np.testing.assert_allclose(flat[17:], 0.0)       # padding gets no grad


# ----------------------------------------------------- engine-level (1 dev)

def _one_step(tc):
    from repro.core import PHubEngine
    from repro.data import SyntheticTokens
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = reduced(ARCHS["llama3.2-1b"], d_model=64)
    eng = PHubEngine(cfg=cfg, tc=tc, mesh=mesh)
    params, opt = eng.init_state(jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, 4, 32, seed=9)
    b = data.batch_at(0)
    shapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in b.items()}
    step = eng.make_train_step(shapes)
    batch = {k: jax.device_put(v, s) for (k, v), s in
             zip(b.items(), eng.batch_shardings(shapes).values())}
    return eng, step, params, opt, batch


def test_flat_residency_matches_tree_step():
    eng_t, step_t, p_t, o_t, batch = _one_step(
        TrainConfig(lr=3e-2, loss_chunk=32))
    p1, o1, m1 = step_t(p_t, o_t, batch)
    eng_f, step_f, p_f, o_f, batch = _one_step(
        TrainConfig(lr=3e-2, loss_chunk=32, flat_residency=True,
                    pipeline_windows=4))
    p2s, o2, m2 = step_f(p_f, o_f, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-6
    back = eng_f.params_from_store(p2s)
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p1, back)
    assert max(jax.tree.leaves(errs)) < 1e-6


def test_flat_residency_rejects_fsdp_stream():
    from repro.core import PHubEngine
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = reduced(ARCHS["llama3.2-1b"], d_model=64)
    with pytest.raises(ValueError, match="flat_residency"):
        PHubEngine(cfg=cfg, tc=TrainConfig(strategy="fsdp_stream",
                                           flat_residency=True), mesh=mesh)


def test_engine_rejects_unknown_optimizer():
    """nesterov/sgd/adam all ride the sharded-optimizer protocol now; an
    optimizer outside the registry must fail fast at engine construction."""
    from repro.core import PHubEngine
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = reduced(ARCHS["llama3.2-1b"], d_model=64)
    with pytest.raises(ValueError, match="unknown optimizer"):
        PHubEngine(cfg=cfg, tc=TrainConfig(optimizer="adagrad"), mesh=mesh)


@pytest.mark.parametrize("optname", ["sgd", "adam"])
def test_engine_runs_protocol_optimizers(optname):
    """One engine step with each protocol optimizer: state structure is
    {dtype: {slot: buffer}} and the step produces finite loss."""
    eng, step, params, opt, batch = _one_step(
        TrainConfig(optimizer=optname, lr=1e-2, loss_chunk=32))
    want = {"sgd": set(), "adam": {"m", "v", "k1", "k2"}}[optname]
    assert {k for d in opt.values() for k in d} == want
    p1, o1, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))


def test_checkpoint_restore_converts_residency(tmp_path):
    """A tree-state checkpoint restores into a flat-residency engine and
    back (checkpointer converts between residency modes)."""
    from repro.checkpoint import save_checkpoint, restore_train_state
    from repro.core import PHubEngine
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = reduced(ARCHS["llama3.2-1b"], d_model=64)
    eng_tree = PHubEngine(cfg=cfg, tc=TrainConfig(), mesh=mesh)
    eng_flat = PHubEngine(cfg=cfg, tc=TrainConfig(flat_residency=True),
                          mesh=mesh)
    params, opt = eng_tree.init_state(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 3, {"params": params, "opt": opt})

    step, store, opt2 = restore_train_state(str(tmp_path), eng_flat)
    assert step == 3
    assert set(store) == {str(g.dtype) for g in eng_flat.chunk_plan.groups}
    back = eng_flat.params_from_store(store)
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, back)
    assert max(jax.tree.leaves(errs)) == 0.0

    # flat checkpoint -> tree engine
    save_checkpoint(str(tmp_path), 4, {"params": store, "opt": opt2})
    _, params2, _ = restore_train_state(str(tmp_path), eng_tree, step=4)
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree.leaves(errs)) == 0.0


# ------------------------------------------------------- zero-copy HLO proof

def _lowered_hlo(tc):
    from repro.utils.hlo import parse_concat_sizes
    eng, step, params, opt, batch = _one_step(tc)
    txt = step.lower(params, opt, batch).compile().as_text()
    group_bytes = max(g.total * np.dtype(g.dtype).itemsize
                      for g in eng.chunk_plan.groups)
    return parse_concat_sizes(txt), group_bytes


def test_flat_residency_train_step_has_no_model_scale_concat():
    """The flat-residency train step must not rebuild whole dtype groups:
    no concatenate at >= half the largest group's bytes.  The tree-state
    step keeps its flatten_groups concats — proving the assertion bites."""
    concats_flat, group_bytes = _lowered_hlo(
        TrainConfig(lr=3e-2, loss_chunk=32, flat_residency=True))
    big = [c for c in concats_flat if c >= group_bytes // 2]
    assert not big, f"model-scale concatenates survived: {big}"

    concats_tree, group_bytes = _lowered_hlo(
        TrainConfig(lr=3e-2, loss_chunk=32))
    assert any(c >= group_bytes // 2 for c in concats_tree), \
        "control failed: tree-state step lost its flatten concats"


# ----------------------------------------------------------- multi-device

@pytest.mark.slow
@pytest.mark.parametrize("case", ["sharded_ps", "hierarchical", "flat",
                                  "ring"])
def test_multidevice_pipeline_parity(case):
    """Pipelined (windows>1) == monolithic, flat == tree, ring == XLA
    psum_scatter — on 8 forced host devices in a subprocess."""
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "multidevice",
                                      "check_pipeline.py"), case],
        capture_output=True, text=True, timeout=1500,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    assert "FAIL" not in proc.stdout
