"""Self-healing training (DESIGN.md §13): fast single-device tests.

Covers the host-side resilience machinery — the health tracker's
running-median threshold and offense streaks, the exchange watchdog's
seeded backoff/exhaustion, durable verified checkpoints (two-phase
writes, CRC manifests, keep-k pruning, corrupt-skip restore), the chaos
fault layer's one-shot semantics, ``Membership.demote`` escalation, and
the in-graph sanity gate on one device.  The multi-device bitwise
claims run in a subprocess (tests/multidevice/check_resilience.py).
"""
import math
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptError, CheckpointError,
                              checkpoint_steps, latest_step,
                              load_checkpoint, prune_checkpoints,
                              restore_latest_valid, save_checkpoint,
                              verify_checkpoint)
from repro.elastic import (FAULT_KINDS, FaultEvent, FaultSchedule,
                           Membership, NAN_PUSH, STALL)
from repro.elastic.chaos import corrupt_checkpoint
from repro.resilience import (ExchangeTimeout, ExchangeWatchdog,
                              HealthTracker, SanityConfig,
                              TransientExchangeError, WatchdogConfig,
                              WatchdogExhausted)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------- health tracker

def test_tracker_warmup_then_median_threshold():
    t = HealthTracker(SanityConfig(norm_factor=4.0, warmup=3), world=4)
    assert t.norm_hi() == float("inf")
    for n in (1.0, 2.0, 3.0):
        t.observe(np.ones(4), np.full(4, n))
    assert t.norm_hi() == pytest.approx(4.0 * 2.0)     # 4 x median{1,2,3}

    # the median only digests healthy workers' norms
    t.observe(np.array([1, 0, 1, 1.0]), np.array([2.0, 1e9, 2.0, 2.0]))
    assert t.norm_hi() == pytest.approx(4.0 * 2.0)


def test_tracker_norm_floor():
    t = HealthTracker(SanityConfig(norm_factor=4.0, warmup=1,
                                   norm_floor=1e-3), world=2)
    t.observe(np.ones(2), np.zeros(2))                 # all-zero warmup
    assert t.norm_hi() == 1e-3


def test_tracker_offense_streaks_and_resets():
    t = HealthTracker(SanityConfig(), world=4)
    bad1 = np.array([1, 0, 1, 1.0])
    t.observe(bad1, np.ones(4))
    t.observe(bad1, np.ones(4))
    assert t.repeat_offenders(2) == [1]
    # a clean step resets the streak
    t.observe(np.ones(4), np.ones(4))
    assert t.repeat_offenders(1) == []
    # dead workers are not convicted for being masked
    t.observe(np.array([1, 0, 1, 0.0]), np.ones(4),
              live_mask=np.array([1, 1, 1, 0.0]))
    assert t.repeat_offenders(1) == [1]
    t.reset_rank(1)
    assert t.repeat_offenders(1) == []
    t.observe(bad1, np.ones(4))
    t.reset_offenses()
    assert t.repeat_offenders(1) == []


# --------------------------------------------------------------- watchdog

def test_watchdog_absorbs_faults_within_budget():
    wd = ExchangeWatchdog(WatchdogConfig(retries=3, backoff_base_s=0.0))
    wd.inject_fault(TransientExchangeError(), attempts=2)
    assert wd.run(lambda: 42) == 42
    assert wd.total_retries == 2
    assert wd.pending_faults() == 0


def test_watchdog_exhaustion_names_the_worker():
    wd = ExchangeWatchdog(WatchdogConfig(retries=1, backoff_base_s=0.0))
    wd.inject_fault(ExchangeTimeout(worker=5), attempts=3)
    with pytest.raises(WatchdogExhausted) as ei:
        wd.run(lambda: 42)
    assert ei.value.worker == 5
    # one queued fault survives the 2 attempts; flushing clears it
    assert wd.pending_faults() == 1
    assert wd.drop_faults(5) == 1
    assert wd.run(lambda: 42) == 42


def test_watchdog_drop_faults_by_worker():
    wd = ExchangeWatchdog(WatchdogConfig(retries=0))
    wd.inject_fault(ExchangeTimeout(worker=1), attempts=2)
    wd.inject_fault(ExchangeTimeout(worker=2), attempts=1)
    assert wd.drop_faults(1) == 2
    assert wd.pending_faults() == 1
    assert wd.drop_faults() == 1


def test_watchdog_backoff_is_seeded_and_capped():
    mk = lambda: ExchangeWatchdog(WatchdogConfig(
        retries=3, backoff_base_s=1e-9, backoff_cap_s=5e-9, jitter=0.5,
        seed=7))
    a, b = mk(), mk()
    for wd in (a, b):
        wd.inject_fault(TransientExchangeError(), attempts=3)
        wd.run(lambda: None)
    assert a.last_delays == b.last_delays               # seeded replay
    assert len(a.last_delays) == 3
    assert all(d <= 5e-9 * 1.5 for d in a.last_delays)  # cap (pre-jitter)


def test_watchdog_overrun_recorded_not_retried():
    wd = ExchangeWatchdog(WatchdogConfig(deadline_s=0.0, retries=3))
    calls = []
    out = wd.run(lambda: calls.append(1) or jnp.ones(3))
    assert len(calls) == 1                              # never re-dispatched
    assert len(wd.overruns) == 1
    assert np.asarray(out).tolist() == [1, 1, 1]


# ---------------------------------------------------- durable checkpoints

def _tree(seed=0, n=37):
    rng = np.random.default_rng(seed)
    return {"params": {"w": rng.normal(size=(n, 5)).astype(np.float32),
                       "b": rng.normal(size=(n,)).astype(np.float32)},
            "opt": {"w": {"m": rng.normal(size=(n, 5)).astype(np.float32)},
                    "b": {"m": rng.normal(size=(n,)).astype(np.float32)}}}


def test_checkpoint_two_phase_and_verify_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, _tree())
        assert verify_checkpoint(d, 3)["step"] == 3
        assert latest_step(d) == 3
        # no tmp litter from the two-phase commit
        assert not [f for f in os.listdir(d) if f.startswith(".tmp")]
        s, tree = load_checkpoint(d)
        assert s == 3
        ref = _tree()
        for k in ("w", "b"):
            np.testing.assert_array_equal(tree["params"][k],
                                          ref["params"][k])


def test_checkpoint_truncation_raises_named_error():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _tree())
        corrupt_checkpoint(d, 1, mode="truncate")
        with pytest.raises(CheckpointCorruptError):
            verify_checkpoint(d, 1)
        with pytest.raises(CheckpointCorruptError):
            load_checkpoint(d, 1)


def _assert_flip_caught_or_harmless(d, ref):
    """The durability contract: a flipped bit either fails verification
    (CRC32 detects all 1-bit data errors) or landed in dead bytes (npy
    header padding, zip bookkeeping slack) — in which case the loaded
    content must still be bitwise the original."""
    try:
        verify_checkpoint(d, 1)
    except CheckpointCorruptError:
        return
    _, tree = load_checkpoint(d, 1)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg="content changed without failing verification"),
        tree, ref)


def test_checkpoint_crc_rejects_seeded_bit_flips():
    """A sweep of seeded flip positions across members and offsets:
    every flip is either caught by name or provably content-neutral."""
    for seed in range(8):
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 1, _tree(seed))
            corrupt_checkpoint(d, 1, mode="bitflip", seed=seed)
            _assert_flip_caught_or_harmless(d, _tree(seed))


def test_checkpoint_missing_manifest_named_half_written():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _tree())
        os.remove(os.path.join(d, "step_00000001", "manifest.json"))
        with pytest.raises(CheckpointCorruptError, match="half-written"):
            verify_checkpoint(d, 1)


def test_checkpoint_keep_k_pruning():
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3):
            save_checkpoint(d, s, _tree(s))
        save_checkpoint(d, 4, _tree(4), keep_k=2)
        assert checkpoint_steps(d) == [3, 4]
        with pytest.raises(ValueError):
            prune_checkpoints(d, 0)


def test_restore_latest_valid_skips_corrupt_and_names_all_bad():
    with tempfile.TemporaryDirectory() as d:
        for s in (2, 4, 6):
            save_checkpoint(d, s, _tree(s))
        corrupt_checkpoint(d, 6, mode="truncate")
        step, params, opt, skipped = restore_latest_valid(d, None)
        assert step == 4 and skipped == [6]
        ref = _tree(4)
        np.testing.assert_array_equal(params["w"], ref["params"]["w"])
        corrupt_checkpoint(d, 4, mode="bitflip")
        corrupt_checkpoint(d, 2, mode="truncate")
        with pytest.raises(CheckpointError):
            restore_latest_valid(d, None)


# ----------------------------------------------------------- fault layer

def test_fault_schedule_seeded_deterministic():
    a = FaultSchedule.seeded(seed=3, world=8, steps=40)
    b = FaultSchedule.seeded(seed=3, world=8, steps=40)
    assert a.events == b.events
    assert len(a.events) > 0
    assert {e.kind for e in a.events} <= set(FAULT_KINDS)
    c = FaultSchedule.seeded(seed=4, world=8, steps=40)
    assert a.events != c.events


def test_fault_schedule_one_shot_consumption_and_reset():
    fs = FaultSchedule([FaultEvent(step=2, kind=NAN_PUSH, worker=1,
                                   duration=2)], world=4)
    v = fs.inject_vector(2)
    assert math.isnan(v[1]) and v[[0, 2, 3]].tolist() == [1, 1, 1]
    assert math.isnan(fs.inject_vector(3)[1])
    # budget (duration=2) is spent: the same steps replay clean
    assert np.all(fs.inject_vector(2) == 1.0)
    fs.reset()
    assert math.isnan(fs.inject_vector(2)[1])
    # faults_at never consumes
    fs.reset()
    assert len(fs.faults_at(2)) == 1
    assert len(fs.faults_at(2)) == 1
    assert math.isnan(fs.inject_vector(2)[1])


def test_fault_schedule_stalls_consume():
    fs = FaultSchedule([FaultEvent(step=1, kind=STALL, worker=2,
                                   magnitude=3)], world=4)
    assert len(fs.stalls_at(1)) == 1
    assert len(fs.stalls_at(1)) == 0                   # one-shot
    fs2 = FaultSchedule([FaultEvent(step=1, kind=STALL, worker=2)],
                        world=4, one_shot=False)
    assert len(fs2.stalls_at(1)) == 1
    assert len(fs2.stalls_at(1)) == 1                  # pure function


def test_membership_demote_escalates():
    m = Membership.full(4)
    m1 = m.demote(2)
    assert m1.workers[2].status == "slow"
    m2 = m1.demote(2)
    assert m2.workers[2].status == "dead"
    with pytest.raises(ValueError, match="nothing to demote"):
        m2.demote(2)


# ------------------------------------------------- sanity gate (1 device)

def test_sanity_gate_single_device():
    from repro.configs import ARCHS, TrainConfig, reduced
    from repro.core import PHubEngine
    from repro.data import SyntheticTokens

    cfg = reduced(ARCHS["llama3.2-1b"], d_model=64)
    eng = PHubEngine(cfg=cfg, tc=TrainConfig(lr=1e-2, loss_chunk=32),
                     mesh=jax.make_mesh((1, 1), ("data", "model")))
    params, opt = eng.init_state(jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, 4, 32, seed=0)
    shapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
              for k, v in data.batch_at(0).items()}
    step = eng.make_train_step(shapes,
                               sanity=SanityConfig(allow_injection=True))
    h = {"norm_hi": np.float32(np.inf), "inject": np.ones((1,), np.float32)}
    params, opt, m = step(params, opt, data.device_batch(0), h)
    assert np.asarray(m["ok_mask"]).tolist() == [1]
    assert float(m["n_live"]) == 1.0
    # a poisoned push is masked; n_live floors at 1; params stay finite
    h_bad = {"norm_hi": np.float32(np.inf),
             "inject": np.full((1,), np.nan, np.float32)}
    params, opt, m = step(params, opt, data.device_batch(1), h_bad)
    assert np.asarray(m["ok_mask"]).tolist() == [0]
    assert float(m["n_live"]) == 1.0
    assert all(np.isfinite(np.asarray(v)).all()
               for v in jax.tree.leaves(params))


def test_fit_supervisor_owns_membership_and_checkpoints():
    from repro.training.loop import TrainState, fit

    with pytest.raises(ValueError, match="owns membership"):
        fit(None, TrainState(params=None, opt=None), None, steps=1,
            checkpoint_dir="/tmp/x", supervisor=object())


def test_fused_health_scan_matches_reference():
    from repro.kernels.agg_opt.ops import fused_health_scan
    from repro.kernels.agg_opt.ref import health_scan_ref

    rng = np.random.default_rng(0)
    for shape in ((513,), (33, 47)):
        g = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        a, b = float(fused_health_scan(g)), float(health_scan_ref(g))
        assert a == pytest.approx(b, rel=1e-5)
    g = jnp.zeros((257,), jnp.float32).at[13].set(jnp.nan)
    assert not np.isfinite(float(fused_health_scan(g)))


# ------------------------------------------- property tests (hypothesis)

# Skipping here must stay test-scoped: a module-level importorskip would
# silently drop every test above when hypothesis is missing (the CI
# tier-1 job asserts zero skips precisely to catch that failure mode).
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*a, **k):                 # no-op decorators so the defs parse
        return lambda f: f
    settings = given

    class st:                           # noqa: N801 - stand-in namespace
        data = integers = floats = lists = staticmethod(
            lambda *a, **k: None)


needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed (hard dep in "
                                "requirements-dev.txt; CI always runs this)")


@needs_hypothesis
@settings(max_examples=25, deadline=None)
@given(st.data())
def test_property_crc_rejects_any_single_bit_flip(data):
    """Flip one arbitrary bit anywhere in the archive: verification must
    fail by name, or — when the flip hit dead bytes — the loaded content
    must be bitwise untouched.  No silent corruption, ever."""
    n = data.draw(st.integers(min_value=1, max_value=64))
    seed = data.draw(st.integers(min_value=0, max_value=2**31 - 1))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, _tree(seed % 97, n=n))
        path = os.path.join(d, "step_00000001", "arrays.npz")
        blob = bytearray(open(path, "rb").read())
        pos = data.draw(st.integers(min_value=0,
                                    max_value=len(blob) - 1))
        bit = data.draw(st.integers(min_value=0, max_value=7))
        blob[pos] ^= 1 << bit
        open(path, "wb").write(bytes(blob))
        _assert_flip_caught_or_harmless(d, _tree(seed % 97, n=n))


@needs_hypothesis
@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(min_value=1e-3, max_value=1e3), min_size=1,
                max_size=64),
       st.floats(min_value=1.5, max_value=64.0))
def test_property_tracker_threshold_bounds(norms, factor):
    """After warmup the threshold is factor x a value inside the observed
    norm range (a running median can never leave [min, max])."""
    t = HealthTracker(SanityConfig(norm_factor=factor, warmup=1,
                                   window=128), world=1)
    for n in norms:
        t.observe(np.ones(1), np.array([n]))
    hi = t.norm_hi()
    assert factor * min(norms) - 1e-9 <= hi <= factor * max(norms) + 1e-9


# ----------------------------------------------------------- multi-device

@pytest.mark.slow
@pytest.mark.parametrize("case", ["nanmask", "rollback", "stallpath",
                                  "e2e"])
def test_multidevice_resilience_oracle(case):
    """Sanity-masked NaN pushes are bitwise the static-membership
    reference at pow-2 live counts; rollback restores the last verified
    snapshot bitwise; stalls demote and re-enter; the 12-device chaos
    acceptance oracle completes unattended — 12 forced host devices."""
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "multidevice",
                                      "check_resilience.py"), case],
        capture_output=True, text=True, timeout=1500,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    assert "FAIL" not in proc.stdout
