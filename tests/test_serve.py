"""Single-device smoke tests for the serving launcher (launch/serve.py)."""
import numpy as np

from repro.launch.serve import main as serve_main

ARGS = ["--arch", "llama3.2-1b", "--reduced", "--batch", "2",
        "--prompt-len", "16", "--decode-steps", "4"]


def test_serve_greedy_smoke():
    gen = serve_main(ARGS)
    assert gen.shape == (2, 4)
    assert gen.dtype == np.int32
    # greedy decoding is deterministic
    gen2 = serve_main(ARGS)
    np.testing.assert_array_equal(np.asarray(gen), np.asarray(gen2))


def test_serve_no_greedy_flag_actually_disables_greedy():
    """--no-greedy must reach the sampling path (the old
    action='store_true', default=True flag could never be turned off)."""
    g_greedy = serve_main(ARGS)
    g_hot = serve_main(ARGS + ["--no-greedy", "--temperature", "5.0",
                               "--seed", "3"])
    assert g_hot.shape == g_greedy.shape
    # at temperature 5 on an untrained model, sampling virtually cannot
    # reproduce the argmax trajectory on all 8 generated tokens
    assert not np.array_equal(np.asarray(g_hot), np.asarray(g_greedy))


def test_serve_sampling_seeded():
    args = ARGS + ["--no-greedy", "--temperature", "2.0", "--seed", "11"]
    a = serve_main(args)
    b = serve_main(args)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
