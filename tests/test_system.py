"""End-to-end behaviour tests for the PHub training/serving system."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, TrainConfig, reduced
from repro.core import PHubConnectionManager, PHubEngine
from repro.data import SyntheticTokens
from repro.checkpoint import save_checkpoint, load_checkpoint, latest_step


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.slow
def test_training_reduces_loss(mesh11):
    """~1M-param llama on the structured synthetic task: loss must drop."""
    cfg = reduced(ARCHS["llama3.2-1b"], d_model=128)
    tc = TrainConfig(lr=5e-2, loss_chunk=64)
    eng = PHubEngine(cfg=cfg, tc=tc, mesh=mesh11)
    params, opt = eng.init_state(jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, batch=8, seq_len=64, seed=0)
    shapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
              for k, v in data.batch_at(0).items()}
    step = eng.make_train_step(shapes)
    losses = []
    for i in range(40):
        params, opt, m = step(params, opt, data.device_batch(i))
        losses.append(float(m["loss"]))
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.5, f"no learning: {first:.3f} -> {last:.3f}"


def test_service_api_multitenancy(mesh11):
    cm = PHubConnectionManager()
    cfg = reduced(ARCHS["llama3.2-1b"], d_model=64)
    h1 = cm.create_service("job-a", cfg, TrainConfig(loss_chunk=32), mesh11)
    h2 = cm.create_service("job-b", cfg, TrainConfig(loss_chunk=32), mesh11)
    assert h1.nonce != h2.nonce

    # bad nonce is rejected (paper: nonce-based isolation)
    from repro.core.api import ServiceHandle
    with pytest.raises(PermissionError):
        cm.connect_service(ServiceHandle(namespace="job-a", nonce="forged"))

    # duplicate namespace rejected
    with pytest.raises(ValueError):
        cm.create_service("job-a", cfg, TrainConfig(), mesh11)

    # the fused PushPull trains job-a without touching job-b
    params, opt = cm.init_service(h1, jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, 4, 32, seed=1)
    batch = data.device_batch(0)
    p1, o1, metrics = cm.push_pull(h1, params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    cm.destroy_service(h2)
    with pytest.raises(PermissionError):
        cm.connect_service(h2)


@pytest.mark.slow
def test_checkpoint_roundtrip_and_resume(mesh11):
    cfg = reduced(ARCHS["rwkv6-3b"], d_model=128)
    tc = TrainConfig(lr=1e-2, loss_chunk=32)
    eng = PHubEngine(cfg=cfg, tc=tc, mesh=mesh11)
    params, opt = eng.init_state(jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, 4, 32, seed=2)
    shapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
              for k, v in data.batch_at(0).items()}
    step = eng.make_train_step(shapes)
    for i in range(3):
        params, opt, _ = step(params, opt, data.device_batch(i))

    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, {"params": params, "opt": opt})
        assert latest_step(d) == 3
        got_step, tree = load_checkpoint(d)
        assert got_step == 3
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(tree["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # resumed state continues training identically
        p_direct, o_direct, m1 = step(params, opt, data.device_batch(3))
        p_res = jax.tree.map(jnp.asarray, tree["params"])
        o_res = jax.tree.map(jnp.asarray, tree["opt"])
        p_resumed, o_resumed, m2 = step(p_res, o_res, data.device_batch(3))
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]),
                                                  abs=1e-5)


def test_serving_pipeline(mesh11):
    """Prefill + batched greedy decode runs and is deterministic."""
    cfg = reduced(ARCHS["h2o-danube-3-4b"], d_model=128)
    eng = PHubEngine(cfg=cfg, tc=TrainConfig(), mesh=mesh11)
    params, _ = eng.init_state(jax.random.PRNGKey(0))
    prompts = (jnp.arange(4 * 16, dtype=jnp.int32).reshape(4, 16)
               % cfg.vocab_size)
    prefill_step = eng.make_prefill_step(16, max_new_tokens=8)
    serve_step = eng.make_serve_step()

    def rollout():
        logits, cache = prefill_step(params, prompts)
        assert logits.shape == (4, cfg.vocab_size)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        toks = [tok]
        for _ in range(4):
            logits, cache_new = serve_step(params, dict(cache), tok)
            cache = cache_new
            assert not bool(jnp.isnan(logits).any())
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            toks.append(tok)
        return jnp.concatenate(toks, 1)

    run1 = rollout()
    run2 = rollout()
    np.testing.assert_array_equal(np.asarray(run1), np.asarray(run2))


@pytest.mark.slow
def test_chunk_size_does_not_change_semantics(mesh11):
    """PHub §3.2.3: the chunk size is a performance knob — results must be
    bit-comparable across chunk sizes."""
    cfg = reduced(ARCHS["llama3.2-1b"], d_model=64)
    data = SyntheticTokens(cfg, 4, 32, seed=3)
    batch = data.device_batch(0)
    shapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
              for k, v in batch.items()}
    outs = []
    for kb in (4, 32, 256):
        tc = TrainConfig(chunk_size_bytes=kb * 1024, loss_chunk=32)
        eng = PHubEngine(cfg=cfg, tc=tc, mesh=mesh11)
        params, opt = eng.init_state(jax.random.PRNGKey(0))
        p1, _, m = eng.make_train_step(shapes)(params, opt, batch)
        outs.append((float(m["loss"]), p1))
    for loss, p in outs[1:]:
        assert loss == pytest.approx(outs[0][0], abs=1e-6)
        for a, b in zip(jax.tree.leaves(outs[0][1]), jax.tree.leaves(p)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=1e-6)


def test_fit_loop(mesh11):
    """training.fit: reusable loop with hooks + checkpointing."""
    from repro.training import fit, TrainState
    cfg = reduced(ARCHS["llama3.2-1b"], d_model=64)
    eng = PHubEngine(cfg=cfg, tc=TrainConfig(lr=3e-2, loss_chunk=32),
                     mesh=mesh11)
    params, opt = eng.init_state(jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, 4, 32, seed=9)
    seen = []
    with tempfile.TemporaryDirectory() as d:
        st = fit(eng, TrainState(params=params, opt=opt), data, steps=6,
                 log_every=0, checkpoint_dir=d, checkpoint_every=3,
                 hooks=[lambda s, m: seen.append(s.step)])
        assert st.step == 6 and len(st.losses) == 6
        assert seen == [1, 2, 3, 4, 5, 6]
        assert latest_step(d) == 6
