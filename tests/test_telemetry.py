"""Telemetry: tracer, metrics registry, attribution, trace round-trip,
and the §17 overhead/program-identity budget (slow)."""
import json
import os
import subprocess
import sys

import pytest

from repro import telemetry
from repro.launch.trace import check_model, load_trace, validate
from repro.telemetry import (MetricsRegistry, Tracer, attribute_step,
                             model_agreement, phase_fractions, step_phases)
from repro.telemetry.tracer import SpanRecord


@pytest.fixture(autouse=True)
def _restore_null_pair():
    yield
    telemetry.disable()


# ------------------------------------------------------------------ tracer

def test_disabled_is_shared_null_noop():
    tr, reg = telemetry.get_tracer(), telemetry.get_registry()
    assert not tr.enabled and not reg.enabled
    with tr.step(0):
        with tr.span("data"):
            pass
    assert tr.records == ()
    assert reg.counter("x").inc(5.0) == 0.0
    assert reg.events() == []


def test_trace_id_is_seeded():
    assert Tracer(seed=5).trace_id == Tracer(seed=5).trace_id
    assert Tracer(seed=5).trace_id != Tracer(seed=6).trace_id


def test_nested_spans_and_step_phases():
    tr, _ = telemetry.enable(seed=0)
    with tr.step(3):
        with tr.span("data"):
            pass
        with tr.span("exchange/push_pull"):
            with tr.span("engine/dispatch"):
                pass
    with tr.span("probe/exchange", rep=0):
        pass
    phases = tr.step_phases()
    # direct children only: the nested engine/dispatch is detail, not a
    # phase (counting it would double-book the step)
    assert set(phases[3]) == {"data", "exchange"}
    assert set(phases[-1]) == {"probe"}
    steps = {r.step for r in tr.records if r.name == "engine/dispatch"}
    assert steps == {3}


def test_chrome_trace_round_trip(tmp_path):
    tr, _ = telemetry.enable(seed=7, meta={"devices": 2})
    for i in range(2):
        with tr.step(i):
            with tr.span("data"):
                pass
            with tr.span("exchange/push_pull", ns="job"):
                pass
    path = tr.write(str(tmp_path / "trace.json"))
    records, meta = load_trace(path)
    assert meta["trace_id"] == tr.trace_id
    assert meta["seed"] == 7 and meta["devices"] == 2
    assert validate(records) == []
    orig, back = tr.step_phases(), step_phases(records)
    assert set(back) == set(orig)
    for i in orig:
        assert set(back[i]) == set(orig[i])
        for ph in orig[i]:
            assert back[i][ph] == pytest.approx(orig[i][ph], abs=5e-6)
    ns = [r.args.get("ns") for r in records
          if r.name == "exchange/push_pull"]
    assert ns == ["job", "job"]


def test_validate_flags_malformed_records():
    bad = [SpanRecord(name="step", t0=0.0, dur=1.0, depth=0, step=0,
                      parent="", args={"step": 0}),
           # claims step 0 but lies outside the step span's interval
           SpanRecord(name="data", t0=5.0, dur=0.1, depth=1, step=0,
                      parent="step"),
           # depth says nested, parent says top-level
           SpanRecord(name="sync", t0=0.2, dur=0.1, depth=2, step=0,
                      parent="")]
    issues = validate(bad)
    assert len(issues) == 2
    assert any("outside" in m for m in issues)
    assert any("inconsistent" in m for m in issues)


# ---------------------------------------------------------------- registry

def test_registry_instruments_and_log(tmp_path):
    reg = MetricsRegistry()
    reg.counter("exchange.bytes").inc(100.0, tenant="a", basis="raw")
    reg.counter("exchange.bytes").inc(50.0, tenant="a", basis="raw")
    assert reg.counter("exchange.bytes").value(tenant="a",
                                               basis="raw") == 150.0
    reg.gauge("membership.epoch").set(3)
    assert reg.gauge("membership.epoch").value() == 3
    reg.histogram("serve.latency").observe(0.005, phase="decode")
    assert reg.histogram("serve.latency").summary(
        phase="decode")["count"] == 1
    reg.current_step = 4
    reg.event("supervisor.demote", rank=2, detail="repeat offender")
    (ev,) = reg.events("supervisor.demote")
    assert ev["step"] == 4 and ev["payload"]["rank"] == 2

    path = str(tmp_path / "metrics.jsonl")
    reg.dump_jsonl(path)
    lines = [json.loads(ln) for ln in open(path)]
    assert len(lines) == 5
    assert {ln["kind"] for ln in lines} == {"counter", "gauge",
                                            "histogram", "event"}
    for ln in lines:
        assert {"kind", "name", "step", "t"} <= set(ln)


def test_registry_kind_conflict():
    reg = MetricsRegistry()
    reg.counter("m")
    with pytest.raises(TypeError):
        reg.gauge("m")


def test_watchdog_emits_metrics():
    from repro.resilience import (ExchangeWatchdog, TransientExchangeError,
                                  WatchdogConfig)
    _, reg = telemetry.enable(seed=0)
    wd = ExchangeWatchdog(WatchdogConfig(retries=2, backoff_base_s=0.0))
    wd.inject_fault(TransientExchangeError(worker=1), attempts=2)
    assert wd.run(lambda: "ok") == "ok"
    assert reg.counter("watchdog.retries").value() == 2
    (r1, r2) = reg.events("watchdog.retry")
    assert r1["payload"]["worker"] == 1 and r2["payload"]["attempt"] == 2


# ------------------------------------------------------------- attribution

PRED = {"comm_s": 0.10, "ici_s": 0.08, "dcn_s": 0.0, "codec_s": 0.02}


def test_attribute_step_scales_model_ratios():
    rows = attribute_step(0.3, 0.2, PRED)
    by = {r["phase"]: r for r in rows}
    # measured exchange 0.2 apportioned over the model's 80/20 split
    assert by["exchange/ici"]["seconds"] == pytest.approx(0.16)
    assert by["exchange/codec"]["seconds"] == pytest.approx(0.04)
    assert by["compute"]["seconds"] == pytest.approx(0.1)
    assert sum(r["fraction"] for r in rows) == pytest.approx(1.0)
    fr = phase_fractions(rows)
    assert fr["exchange/ici"] == pytest.approx(0.16 / 0.3, abs=1e-3)


def test_attribute_step_without_model_keeps_measured_exchange():
    rows = attribute_step(0.3, 0.2, None, host_phases={"data": 0.01})
    by = {r["phase"]: r for r in rows}
    assert by["exchange"]["seconds"] == pytest.approx(0.2)
    assert by["exchange"]["measured"] is True
    assert by["compute"]["seconds"] == pytest.approx(0.09)
    assert by["data"]["seconds"] == pytest.approx(0.01)


def test_model_agreement_band():
    ok = model_agreement(0.11, PRED, rel_tol=0.2)
    assert ok["checked"] and ok["ok"] and ok["ratio"] == pytest.approx(1.1)
    bad = model_agreement(0.2, PRED, rel_tol=0.2)
    assert bad["checked"] and not bad["ok"]
    assert model_agreement(None, PRED, 0.2) == {"checked": False,
                                                "ok": True}


def test_check_model_reads_embedded_metadata(tmp_path):
    tr, _ = telemetry.enable(seed=0)
    for r in range(3):
        with tr.span("probe/exchange", rep=r):
            pass
    measured = sorted(x.dur for x in tr.records)[1]
    tr.meta["attribution"] = {"predicted": {"comm_s": measured},
                              "rel_tol": 0.5}
    path = tr.write(str(tmp_path / "t.json"))
    records, meta = load_trace(path)
    ag = check_model(records, meta)
    assert ag["checked"] and ag["ok"]
    assert ag["ratio"] == pytest.approx(1.0, abs=1e-3)
    # no attribution metadata -> impossible, not silently ok
    assert not check_model(records, {})["ok"]


# -------------------------------------------------- overhead budget (§17)

@pytest.mark.slow
def test_overhead_budget_and_program_identity():
    """Telemetry on must stay within 2% of off on the 8-device
    zero-compute step and lower a byte-identical program."""
    from repro.tuning.tuner import _ROOT, _subprocess_env
    payload = {"bench": "telemetry_overhead", "devices": 8, "reps": 15}
    proc = subprocess.run(
        [sys.executable, os.path.join(_ROOT, "benchmarks", "_mdworker.py"),
         json.dumps(payload)],
        capture_output=True, text=True, timeout=900,
        env=_subprocess_env(8))
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["hlo_identical"], "tracing changed the lowered program"
    assert out["spans_recorded"] > 0
    assert out["overhead"] <= 0.02, (
        f"telemetry overhead {out['overhead']:.1%} exceeds the 2% budget "
        f"(off {out['us_off']:.0f}us on {out['us_on']:.0f}us)")
