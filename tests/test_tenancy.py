"""Multi-tenant co-scheduled exchange (§3.1, DESIGN.md §9).

Single-device tests cover the packed-domain math at the engine level; the
8-device oracle equivalence (co-scheduled == per-tenant solo, bitwise, for
sharded_ps and hierarchical with pipeline_windows in {1, 2}, plus the
attach/detach momentum lifecycle) runs in a subprocess like
tests/test_pipeline.py.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, TrainConfig, reduced
from repro.core import PHubConnectionManager, pack_domains
from repro.core.cost_model import tenant_accounting, tenant_step_traffic

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ----------------------------------------------------------- domain/engine

def _manager_with_tenants(n, mesh, **tc_kw):
    cm = PHubConnectionManager()
    handles = []
    for i in range(n):
        cfg = reduced(ARCHS["llama3.2-1b"], d_model=64 * (i + 1))
        tc = TrainConfig(lr=1e-2 * (i + 1), loss_chunk=32, **tc_kw)
        h = cm.create_service(f"job{i}", cfg, tc, mesh)
        cm.attach_service(h)
        handles.append(h)
    return cm, handles


def test_packed_domain_tracks_attached_set():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cm, handles = _manager_with_tenants(3, mesh)
    dom = cm.packed_domain
    assert dom.tenants == ("job0", "job1", "job2")
    (g,) = dom.groups.values()
    # packed domain holds every tenant's total exactly once
    assert sum(s.total for s in g.slots) == sum(
        cm._services[h.namespace].engine.chunk_plan.groups[0].total
        for h in handles)
    cm.detach_service(handles[1])
    assert cm.packed_domain.tenants == ("job0", "job2")


def test_coef_vector_marks_tenant_ranges():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cm, _ = _manager_with_tenants(2, mesh)
    dom = cm.packed_domain
    (key,) = dom.groups
    g = dom.groups[key]
    lr = dom.coef_vector(key, {"job0": 1.0, "job1": 2.0})
    for slot, want in zip(g.slots, (1.0, 2.0)):
        for toff, poff, length in slot.runs:
            assert (lr[poff:poff + length] == want).all()
    # pad positions carry the fill value (fixed points of the update)
    covered = np.zeros(g.padded, bool)
    for slot in g.slots:
        for _, poff, length in slot.runs:
            covered[poff:poff + length] = True
    assert (lr[~covered] == 0.0).all()


def test_tenant_accounting_shares_sum_to_one():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cm, _ = _manager_with_tenants(3, mesh)
    acct = tenant_accounting(cm.packed_domain, "sharded_ps", 4)
    assert abs(sum(a["domain_share"] for a in acct.values()) - 1.0) < 1e-9
    t = tenant_step_traffic("sharded_ps", 100.0, 4)
    assert t["push_bytes"] == t["pull_bytes"] == 75.0
    assert tenant_step_traffic("centralized_ps", 100.0, 4)["push_bytes"] == 100.0


@pytest.mark.slow
def test_single_tenant_coschedule_matches_solo():
    """K=1 co-scheduling is the solo engine in a different coat — bitwise."""
    from repro.data import SyntheticTokens
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = reduced(ARCHS["llama3.2-1b"], d_model=64)
    tc = TrainConfig(lr=3e-2, loss_chunk=32)
    b = SyntheticTokens(cfg, 4, 32, seed=5).batch_at(0)

    cm = PHubConnectionManager()
    h = cm.create_service("solo", cfg, tc, mesh)
    p, o = cm.init_service(h, jax.random.PRNGKey(0))
    for _ in range(2):
        p, o, m = cm.push_pull(h, p, o, b)

    cm2 = PHubConnectionManager()
    h2 = cm2.create_service("solo", cfg, tc, mesh)
    p2, _ = cm2.init_service(h2, jax.random.PRNGKey(0))
    cm2.attach_service(h2)
    params = {"solo": p2}
    for _ in range(2):
        params, metrics = cm2.co_step([h2], params, {"solo": b})
    errs = jax.tree.map(
        lambda a, b: int((np.asarray(a) != np.asarray(b)).sum()),
        p, params["solo"])
    assert sum(jax.tree.leaves(errs)) == 0
    assert float(m["loss"]) == float(metrics["solo"]["loss"])


def test_pack_domains_rejects_mismatched_chunk_size():
    tree = {"w": jax.ShapeDtypeStruct((4096,), np.float32)}
    from repro.core.chunking import build_plan
    a = build_plan(tree, chunk_bytes=1024, n_shards=2)
    b = build_plan(tree, chunk_bytes=512, n_shards=2)
    with pytest.raises(ValueError, match="chunk size"):
        pack_domains({"A": a, "B": b}, n_shards=2, chunk_bytes=1024)


# ----------------------------------------------------------- multi-device

@pytest.mark.slow
@pytest.mark.parametrize("case", ["sharded_ps", "hierarchical", "lifecycle"])
def test_multidevice_tenancy_oracle(case):
    """Two co-scheduled tenants == each tenant trained alone (bitwise), on
    8 forced host devices in a subprocess."""
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "multidevice",
                                      "check_tenancy.py"), case],
        capture_output=True, text=True, timeout=1500,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    assert "FAIL" not in proc.stdout
