"""Launcher flag-conflict matrix (launch/train.py).

The supervised loop hands worker membership to the TrainSupervisor, so a
``--chaos``/``--elastic`` membership schedule combined with
``--supervise``/``--chaos-faults`` used to be silently discarded (the
launcher branched into the supervised loop before constructing the
schedule).  ``resolve_mode_flags`` now fails fast on every such pair —
this matrix pins the exact accept/reject decision for all 16 flag
combinations, plus the two implications (--chaos-faults => --supervise,
--chaos => --elastic).
"""
import itertools

import pytest

from repro.launch.train import resolve_mode_flags

ALL_COMBOS = list(itertools.product((False, True), repeat=4))


@pytest.mark.parametrize(
    "supervise,elastic,chaos,chaos_faults", ALL_COMBOS,
    ids=["+".join(n for n, v in zip(("sup", "ela", "cha", "flt"), c) if v)
         or "none" for c in ALL_COMBOS])
def test_flag_matrix(supervise, elastic, chaos, chaos_faults):
    wants_supervisor = supervise or chaos_faults
    wants_membership = elastic or chaos
    if wants_supervisor and wants_membership:
        with pytest.raises(SystemExit) as e:
            resolve_mode_flags(supervise, elastic, chaos, chaos_faults)
        msg = str(e.value)
        # the error names BOTH sides of the conflict, preferring the
        # flag the user actually typed over the implied one
        assert ("--chaos-faults" if chaos_faults else "--supervise") in msg
        assert ("--chaos" if chaos else "--elastic") in msg
    else:
        sup, ela = resolve_mode_flags(supervise, elastic, chaos,
                                      chaos_faults)
        assert sup == wants_supervisor     # --chaos-faults => --supervise
        assert ela == wants_membership     # --chaos => --elastic


def test_valid_modes_pass_through():
    # the three supported launch modes resolve without error
    assert resolve_mode_flags(False, False, False, False) == (False, False)
    assert resolve_mode_flags(False, False, True, False) == (False, True)
    assert resolve_mode_flags(True, False, False, True) == (True, False)


def test_conflict_message_names_silent_discard():
    with pytest.raises(SystemExit, match="silently discarded"):
        resolve_mode_flags(True, False, True, False)
