"""Autotuner unit tests (DESIGN.md §16): search-space validity, the
winner cache, and the rank -> time -> lint-gate -> cache flow with
injected timer/linter fakes — no subprocesses, no devices.  The real
subprocess seams (tuner_candidate timing, launch/lint.py --tuned) are
exercised by launch/tune.py in CI and benchmarks/autotune.py.
"""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.configs import TrainConfig
from repro.core.pipeline import PIPELINED_STRATEGIES
from repro.tuning import (autotune, cache_key, cache_path, enumerate_space,
                          load_cached, mesh_shapes, rank_candidates,
                          store_winner, valid)
from repro.tuning.space import Candidate
from repro.tuning.tuner import _incumbent

LIKE = {"w": jax.ShapeDtypeStruct((4096, 16), jnp.float32),
        "b": jax.ShapeDtypeStruct((300,), jnp.float32)}

QUIET = dict(log=lambda *a, **k: None)


def ok_linter(c):
    return {"ok": True, "errors": []}


def entry_for(c, us=100.0, ok=True):
    return {"candidate": c.to_dict(), "predicted": {"seconds": 1e-4},
            "measured_us": us, "lint": {"ok": ok, "errors": []},
            "devices": 8, "steps": 5, "leaderboard": [], "rejected": []}


# ------------------------------------------------------------------ space

def test_enumerated_space_is_valid_and_deduplicated():
    space = enumerate_space(8)
    assert space and len(space) == len(set(space))
    for c in space:
        assert valid(c, 8)
        assert c.pods * c.data == 8 and c.data >= 2
        if c.strategy == "hierarchical":
            assert c.pods > 1
        if c.strategy == "allreduce":
            assert c.pods == 1
        if c.strategy not in PIPELINED_STRATEGIES:
            assert c.pipeline_windows == 1
            assert c.wire_format == "identity"
        if c.wire_format_dcn not in (None, "identity"):
            assert c.strategy == "hierarchical" and c.pods > 1


def test_mesh_shapes_factor_device_count():
    assert mesh_shapes(8) == [(1, 8), (2, 4), (4, 2)]
    assert mesh_shapes(2) == [(1, 2)]


def test_rank_candidates_sorted_and_complete():
    ranked = rank_candidates(LIKE, enumerate_space(8))
    secs = [p["seconds"] for _, p in ranked]
    assert secs == sorted(secs)
    # every strategy the space admits survives the cost model
    assert {c.strategy for c, _ in ranked} == {"allreduce", "sharded_ps",
                                               "hierarchical"}


# ------------------------------------------------------------------ cache

def test_cache_roundtrip_and_lint_distrust(tmp_path):
    c = Candidate("sharded_ps", 1, "identity", None, 32 * 1024, 1, 8)
    key = cache_key(TrainConfig(), 8, LIKE)
    store_winner(key, entry_for(c), str(tmp_path))
    got = load_cached(key, str(tmp_path))
    assert got["candidate"] == c.to_dict()
    # a red lint verdict is never trusted — forces a re-tune
    store_winner(key, entry_for(c, ok=False), str(tmp_path))
    assert load_cached(key, str(tmp_path)) is None
    # corruption degrades to a miss, not a crash
    with open(cache_path(key, str(tmp_path)), "w") as f:
        f.write("{not json")
    assert load_cached(key, str(tmp_path)) is None


def test_cache_key_tracks_request_not_winner():
    base = cache_key(TrainConfig(), 8, LIKE)
    assert base == cache_key(TrainConfig(), 8, LIKE)
    assert base != cache_key(TrainConfig(), 4, LIKE)
    assert base != cache_key(TrainConfig(wire_format_dcn="int8"), 8, LIKE)
    other = {"w": jax.ShapeDtypeStruct((4096, 17), jnp.float32),
             "b": LIKE["b"]}
    assert base != cache_key(TrainConfig(), 8, other)


# -------------------------------------------------------------- autotune

# a controlled space: the analytic rank order over these is irrelevant to
# the tests below — the fakes decide the measured order
CANDS = [Candidate("sharded_ps", 1, "identity", None, 32 * 1024, 1, 8),
         Candidate("sharded_ps", 2, "bf16", None, 8 * 1024, 1, 8),
         Candidate("sharded_ps", 2, "int8", None, 8 * 1024, 1, 8),
         Candidate("hierarchical", 2, "identity", "int8", 8 * 1024, 2, 4)]


def test_autotune_flow_and_cache_hit(tmp_path):
    timed = []

    def timer(c):
        timed.append(c)
        return 50.0 if c.wire_format == "bf16" else 100.0

    report = autotune(LIKE, TrainConfig(), 8, cache_dir=str(tmp_path),
                      candidates=CANDS, top_k=4, timer=timer,
                      linter=ok_linter, **QUIET)
    assert not report["cache_hit"]
    assert report["timed_candidates"] == len(timed)
    assert report["candidate"]["wire_format"] == "bf16"
    us = [r["us"] for r in report["leaderboard"]]
    assert us == sorted(us)
    # second invocation: zero timed steps, same winner
    n_before = len(timed)
    again = autotune(LIKE, TrainConfig(), 8, cache_dir=str(tmp_path),
                     candidates=CANDS, top_k=4, timer=timer,
                     linter=ok_linter, **QUIET)
    assert again["cache_hit"] and again["timed_candidates"] == 0
    assert len(timed) == n_before
    assert again["candidate"] == report["candidate"]
    # force re-tunes
    forced = autotune(LIKE, TrainConfig(), 8, cache_dir=str(tmp_path),
                      candidates=CANDS, top_k=4, force=True, timer=timer,
                      linter=ok_linter, **QUIET)
    assert not forced["cache_hit"] and len(timed) > n_before


def test_autotune_lint_gate_falls_through(tmp_path):
    def linter(c):
        if c.wire_format == "bf16":
            return {"ok": False, "errors": [{"message": "R1"}]}
        return {"ok": True, "errors": []}

    report = autotune(LIKE, TrainConfig(), 8, cache_dir=str(tmp_path),
                      candidates=CANDS, top_k=4,
                      timer=lambda c: 50.0 if c.wire_format == "bf16"
                      else 100.0,
                      linter=linter, **QUIET)
    assert report["candidate"]["wire_format"] != "bf16"
    assert any(r["candidate"]["wire_format"] == "bf16"
               for r in report["rejected"])
    # the cached entry is the gated winner, loadable
    assert load_cached(report["key"],
                       str(tmp_path))["candidate"] == report["candidate"]


def test_autotune_all_rejected_fails_closed(tmp_path):
    with pytest.raises(RuntimeError, match="lint-rejected"):
        autotune(LIKE, TrainConfig(), 8, cache_dir=str(tmp_path),
                 top_k=2, timer=lambda c: 1.0,
                 linter=lambda c: {"ok": False, "errors": []}, **QUIET)
    # a failed tune must not poison the cache
    assert load_cached(cache_key(TrainConfig(), 8, LIKE),
                       str(tmp_path)) is None


def test_autotune_timing_failures_are_skipped(tmp_path):
    def timer(c):
        if c.strategy == "allreduce":
            raise RuntimeError("worker died")
        return 10.0

    report = autotune(
        LIKE, TrainConfig(), 8, cache_dir=str(tmp_path),
        candidates=[Candidate("allreduce", 1, "identity", None,
                              32 * 1024, 1, 8),
                    Candidate("sharded_ps", 1, "identity", None,
                              32 * 1024, 1, 8)],
        timer=timer, linter=ok_linter, **QUIET)
    assert report["candidate"]["strategy"] == "sharded_ps"


def test_autotune_always_times_the_incumbent(tmp_path):
    """Even when the cost model ranks the caller's baseline config out of
    the top-k (or clean out of a restricted space), it gets timed — a
    mispriced model cannot crown a winner slower than the default."""
    timed = []

    def timer(c):
        timed.append(c)
        return 10.0 if c == _incumbent(TrainConfig(), 8) else 99.0

    restricted = [Candidate("sharded_ps", 2, "int8", None, 8 * 1024, 1, 8)]
    report = autotune(LIKE, TrainConfig(), 8, cache_dir=str(tmp_path),
                      candidates=restricted, timer=timer,
                      linter=ok_linter, **QUIET)
    inc = _incumbent(TrainConfig(), 8)
    assert inc in timed
    assert Candidate.from_dict(report["candidate"]) == inc


def test_incumbent_mirrors_the_train_config():
    inc = _incumbent(TrainConfig(), 8)
    assert inc == Candidate("sharded_ps", 1, "identity", None, 32 * 1024,
                            1, 8)
    # a hierarchical baseline has no flat-mesh expression
    assert _incumbent(TrainConfig(strategy="hierarchical"), 8) is None


def test_autotune_report_is_json_serializable(tmp_path):
    report = autotune(LIKE, TrainConfig(), 8, cache_dir=str(tmp_path),
                      top_k=2, timer=lambda c: 1.0, linter=ok_linter,
                      **QUIET)
    json.dumps(report)
