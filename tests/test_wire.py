"""Wire-format layer (core/wire.py, DESIGN.md §11): formats, slot layout,
validation, error feedback, legacy checkpoints, and byte accounting.

Single-device here (the PS pull path is encoded + error-fed even at S=1);
the 8-device encoded ring — windowed-vs-monolithic determinism, the
multi-worker int8 convergence run, and the residual migration lifecycle —
runs in tests/multidevice/check_client.py (slow tier).  Hypothesis
property tests for the codec live in tests/test_wire_properties.py.
"""
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, TrainConfig, reduced
from repro.core import PHubClient, PHubConnectionManager, PHubEngine
from repro.core.wire import (WIRE_EF_SLOT, WIRE_FORMATS, WireFormat,
                             make_wire_format)

ROOT = os.path.join(os.path.dirname(__file__), "..")

LIKE = {"dense": {"w": jax.ShapeDtypeStruct((64, 48), jnp.float32),
                  "b": jax.ShapeDtypeStruct((48,), jnp.float32)},
        "scale": jax.ShapeDtypeStruct((17,), jnp.float32)}


def _mesh():
    return jax.make_mesh((1,), ("data",))


# ---------------------------------------------------------------- formats

def test_wire_format_registry():
    assert make_wire_format(TrainConfig()).is_identity
    with pytest.raises(ValueError, match="unknown wire format"):
        WireFormat("int4")
    for name in WIRE_FORMATS:
        w = WireFormat(name)
        assert w.error_feedback == (name != "identity")
        assert w.has_scales == (name == "int8")


def test_wire_dtype_and_payload_bytes():
    w = WireFormat("int8")
    assert w.wire_dtype(np.float32) == np.int8
    # 1 byte/elem + one f32 scale per 256-elem chunk
    assert w.payload_bytes(1024, np.float32, 256) == 1024 + 4 * 4
    assert WireFormat("bf16").payload_bytes(1024, np.float32, 256) == 2048
    assert WireFormat("identity").payload_bytes(1024, np.float32, 256) == 4096
    assert WireFormat("int8").compression_factor(np.float32, 8192) > 3.9


def test_extra_slots_rides_last():
    tc = TrainConfig(wire_format="int8", chunk_size_bytes=1024)
    client = PHubClient(tc, _mesh()).register(LIKE)
    names = [s.name for s in client.exchange_slots]
    assert names == ["m", WIRE_EF_SLOT]
    shapes = client.slot_shapes()
    assert set(shapes["float32"]) == {"m", WIRE_EF_SLOT}
    assert shapes["float32"][WIRE_EF_SLOT].dtype == np.float32
    # identity wire adds nothing: the pre-wire layout, bitwise
    c0 = PHubClient(TrainConfig(chunk_size_bytes=1024), _mesh())
    assert [s.name for s in c0.exchange_slots] == ["m"]


# ------------------------------------------------------------- validation

def test_wire_needs_shard_dimension():
    for strategy in ("allreduce", "centralized_ps"):
        with pytest.raises(ValueError, match="shard dimension"):
            PHubClient(TrainConfig(strategy=strategy, wire_format="int8"),
                       _mesh())
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    cfg = reduced(ARCHS["llama3.2-1b"], d_model=64)
    for strategy in ("allreduce", "fsdp_stream"):
        with pytest.raises(ValueError, match="wire format"):
            PHubEngine(cfg=cfg, tc=TrainConfig(strategy=strategy,
                                               wire_format="int8"),
                       mesh=mesh2)


def test_exchange_signature_includes_wire_format():
    a = TrainConfig(wire_format="identity")
    b = TrainConfig(wire_format="int8")
    assert a.exchange_signature() != b.exchange_signature()


def test_attach_fails_fast_on_wire_mismatch():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = reduced(ARCHS["llama3.2-1b"], d_model=64)
    cm = PHubConnectionManager()
    h1 = cm.create_service("a", cfg, TrainConfig(loss_chunk=32), mesh)
    h2 = cm.create_service("b", cfg, TrainConfig(loss_chunk=32,
                                                 wire_format="int8"), mesh)
    cm.attach_service(h1)
    with pytest.raises(ValueError, match="wire format"):
        cm.attach_service(h2)


# --------------------------------------------------------- error feedback

@pytest.mark.parametrize("wf", ["bf16", "int8"])
def test_push_pull_encoded_wire_engages_residual(wf):
    """The pull path quantizes the parameter delta and carries the
    rounding error forward: the residual is nonzero after a step and the
    two-step trajectory differs from (tracks) the identity wire."""
    rng = np.random.default_rng(3)
    isl = lambda t: isinstance(t, jax.ShapeDtypeStruct)
    mk = lambda s, lead=None: jnp.asarray(
        rng.normal(size=((lead,) + s.shape) if lead else s.shape)
    ).astype(s.dtype)
    params0 = jax.tree.map(lambda s: mk(s), LIKE, is_leaf=isl)
    grads = jax.tree.map(lambda s: mk(s, 1), LIKE, is_leaf=isl)

    outs = {}
    for name in ("identity", wf):
        tc = TrainConfig(optimizer="nesterov", lr=3e-2,
                         chunk_size_bytes=1024, wire_format=name)
        client = PHubClient(tc, _mesh()).register(LIKE)
        p = jax.tree.map(lambda x: x + 0, params0)
        o = client.init_state()
        for _ in range(2):
            p, o = client.push_pull(grads, p, o)
        outs[name] = (p, o)
    p_id, _ = outs["identity"]
    p_w, o_w = outs[wf]
    res = np.asarray(o_w["float32"][WIRE_EF_SLOT]).reshape(-1)
    assert np.abs(res).max() > 0            # error feedback engaged
    err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(np.abs(np.asarray(a, np.float32)
                                  - np.asarray(b, np.float32)).max()),
        p_id, p_w)))
    assert 0 < err < 0.05                   # differs, but tracks identity


def _mlp_losses(wire_format, steps=80):
    """Tiny regression MLP through PHubClient; returns the loss curve."""
    tc = TrainConfig(optimizer="adam", lr=1e-2, strategy="sharded_ps",
                     chunk_size_bytes=1024, wire_format=wire_format)
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params = {"w1": jax.random.normal(k1, (16, 32)) * 0.25,
              "w2": jax.random.normal(k2, (32, 4)) * 0.18}

    def loss_fn(p, x, y):
        return jnp.mean((jnp.tanh(x @ p["w1"]) @ p["w2"] - y) ** 2)

    client = PHubClient(tc, _mesh()).register(params)
    opt = client.init_state()
    x = jax.random.normal(jax.random.PRNGKey(7), (256, 16))
    y = jnp.tanh(x @ jax.random.normal(jax.random.PRNGKey(8), (16, 4)))
    grad = jax.jit(jax.grad(loss_fn))
    lval = jax.jit(loss_fn)
    losses = []
    for _ in range(steps):
        g = grad(params, x, y)
        params, opt = client.push_pull(
            jax.tree.map(lambda v: v[None], g), params, opt)
        losses.append(float(lval(params, x, y)))
    return losses


def test_int8_error_feedback_tracks_fp32_convergence():
    """Small-MLP convergence oracle: the int8+error-feedback loss curve
    tracks the fp32 (identity-wire) curve (single-device flavor — the
    pull path is quantized; the multi-worker quantized push runs in the
    8-device check)."""
    ref = _mlp_losses("identity")
    q = _mlp_losses("int8")
    assert ref[-1] < 0.2 * ref[0]           # the task is learnable
    assert q[-1] < 0.2 * q[0]               # quantized run learns too
    # curves track: endpoint within 20% of the fp32 loss drop
    drop = ref[0] - ref[-1]
    assert abs(q[-1] - ref[-1]) < 0.2 * drop


# ------------------------------------------- window invariance (structural)

def test_encode_commutes_with_chunk_aligned_windows():
    """enc(x)[window] == enc(x[window]) bitwise for chunk-aligned windows
    — the codec never sees window boundaries, the structural half of the
    windowed == monolithic determinism claim (the other half is that the
    ring visits rows in the same order regardless of W)."""
    rng = np.random.default_rng(5)
    ce, n = 64, 64 * 8
    x = jnp.asarray(rng.normal(size=n).astype(np.float32) * 10)
    for wf in ("bf16", "int8"):
        wire = WireFormat(wf)
        whole = wire.encode(x, ce)
        for W in (2, 4):
            Lw = n // W
            for w in range(W):
                sl = slice(w * Lw, (w + 1) * Lw)
                parts = wire.encode(x[sl], ce)
                np.testing.assert_array_equal(
                    np.asarray(whole[0][sl]), np.asarray(parts[0]))
                if wire.has_scales:
                    np.testing.assert_array_equal(
                        np.asarray(whole[1][sl.start // ce:sl.stop // ce]),
                        np.asarray(parts[1]))


def test_ring_schedule_window_invariant_eager():
    """Eager (per-op compiled, no cross-program fusion) simulation of the
    encoded ring reduce-scatter: splitting the shard into W windows
    produces bitwise the same reduced values as the monolithic pass —
    window partitioning is invisible to the wire arithmetic.  The jitted
    8-device check (check_client.py case 'wire') asserts the same to one
    quantization grid step, the residual slack XLA:CPU's cross-program
    FMA/rounding-elision jitter needs (DESIGN.md §11)."""
    rng = np.random.default_rng(9)
    S, L, ce = 4, 512, 64
    rows = rng.normal(size=(S, L)).astype(np.float32) * 5

    def reduce_ring(W, wf):
        wire = WireFormat(wf)
        Lw = L // W
        out = np.zeros(L, np.float32)
        for w in range(W):
            sl = slice(w * Lw, (w + 1) * Lw)
            carry = wire.encode(jnp.asarray(rows[0, sl]), ce)
            for k in range(1, S - 1):
                acc = wire.decode(carry, ce) + jnp.asarray(rows[k, sl])
                carry = wire.encode(acc, ce)
            out[sl] = np.asarray(wire.decode(carry, ce)
                                 + jnp.asarray(rows[S - 1, sl]))
        return out

    for wf in ("bf16", "int8"):
        np.testing.assert_array_equal(reduce_ring(1, wf),
                                      reduce_ring(2, wf))
        np.testing.assert_array_equal(reduce_ring(1, wf),
                                      reduce_ring(4, wf))


# ------------------------------------------------------------- checkpoints

def test_checkpoint_wire_residual_roundtrip_and_legacy(tmp_path):
    """wire_ef round-trips bitwise; a pre-wire checkpoint restores into an
    encoded-wire engine with a fresh residual; an encoded-wire checkpoint
    restores into an identity engine by dropping the residual."""
    from repro.checkpoint import restore_train_state, save_checkpoint
    from repro.data import SyntheticTokens
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = reduced(ARCHS["llama3.2-1b"], d_model=64)
    tc = TrainConfig(optimizer="sgd", loss_chunk=32, wire_format="int8")
    eng = PHubEngine(cfg=cfg, tc=tc, mesh=mesh)
    params, opt = eng.init_state(jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, 4, 32, seed=2)
    b = data.batch_at(0)
    shapes = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
              for k, v in b.items()}
    step = eng.make_train_step(shapes)
    batch = {k: jax.device_put(v, s) for (k, v), s in
             zip(b.items(), eng.batch_shardings(shapes).values())}
    params, opt, _ = step(params, opt, batch)
    assert all(WIRE_EF_SLOT in d for d in opt.values())
    save_checkpoint(str(tmp_path), 1, {"params": params, "opt": opt})

    st, p2, o2 = restore_train_state(str(tmp_path), eng)
    bad = jax.tree.map(
        lambda a, b: int((np.asarray(a) != np.asarray(b)).sum()),
        (params, opt), (p2, o2))
    assert sum(jax.tree.leaves(bad)) == 0

    # encoded-wire ckpt -> identity engine: residual dropped by design
    eng_id = PHubEngine(cfg=cfg, tc=dataclasses.replace(
        tc, wire_format="identity"), mesh=mesh)
    st, p3, o3 = restore_train_state(str(tmp_path), eng_id)
    assert all(WIRE_EF_SLOT not in d for d in o3.values())

    # identity ckpt -> encoded-wire engine: residual starts from zero
    p_id, o_id = eng_id.init_state(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 2, {"params": p_id, "opt": o_id})
    st, p4, o4 = restore_train_state(str(tmp_path), eng, step=2)
    for d in o4.values():
        assert float(np.abs(np.asarray(d[WIRE_EF_SLOT])).max()) == 0.0
    params, opt, m = step(p4, o4, batch)     # restored state still trains
    assert np.isfinite(float(m["loss"]))


# --------------------------------------------------------- byte accounting

def test_cost_model_wire_traffic():
    from repro.core import cost_model
    tr = cost_model.tenant_step_traffic("sharded_ps", 4096.0, 4,
                                        wire_bytes=1024.0)
    assert tr["push_bytes"] == 4096.0 * 3 / 4
    assert tr["wire_push_bytes"] == 1024.0 * 3 / 4
    # identity default: wire == raw
    tr0 = cost_model.tenant_step_traffic("sharded_ps", 4096.0, 4)
    assert tr0["wire_push_bytes"] == tr0["push_bytes"]


def test_tenant_accounting_reports_wire_bytes():
    from repro.core import cost_model
    from repro.core.chunking import build_plan, pack_domains
    plans = {f"job{i}": build_plan(
        {"w": jnp.zeros((1000 + 100 * i,), jnp.float32)},
        chunk_bytes=256, n_shards=2) for i in range(2)}
    dom = pack_domains(plans, n_shards=2, chunk_bytes=256)
    acct = cost_model.tenant_accounting(dom, "sharded_ps", 2,
                                        wire=WireFormat("int8"))
    for ns, a in acct.items():
        assert a["wire_bytes"] < a["model_bytes"]
        assert 3.5 < a["compression"] < 4.1
        assert a["per_step"]["wire_push_bytes"] < a["per_step"]["push_bytes"]
    # no wire: the rack still carries whole chunk-aligned slots, so the
    # raw figure is the padded residency, not the unpadded model bytes
    acct0 = cost_model.tenant_accounting(dom, "sharded_ps", 2)
    for ns, a in acct0.items():
        assert a["wire_bytes"] == a["padded_bytes"]
        assert a["wire_bytes"] >= a["model_bytes"]


# ------------------------------------------------------------ benchmarks

def test_benchmark_run_only_filter():
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.run import MODULES, select_modules
    finally:
        sys.path.pop(0)
    assert select_modules([]) == tuple(MODULES)
    assert select_modules(["--only", "wire_sweep"]) == ("wire_sweep",)
    assert select_modules(["--only", "wire_sweep,roofline"]) == \
        ("wire_sweep", "roofline")
    with pytest.raises(SystemExit, match="unknown benchmark"):
        select_modules(["--only", "nope"])
    with pytest.raises(SystemExit, match="unknown benchmark"):
        select_modules(["nope"])
