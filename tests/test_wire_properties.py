"""Hypothesis property tests for the wire codec (core/wire.py §11).

Two contracts the exchange datapath rests on:
  * the int8 blockwise quantize/dequantize roundtrip error is bounded by
    scale/2 per element (round-to-nearest within each chunk's scale);
  * the encoded payload + per-chunk scale layout tiles the chunk domain
    exactly once — scale k governs elements [k*ce, (k+1)*ce) and nothing
    else, which is what makes window boundaries (whole chunks) invisible
    to the codec and windowed == monolithic encoded schedules exact.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.chunking import build_plan, chunk_spans  # noqa: E402
from repro.core.wire import WireFormat  # noqa: E402
from repro.kernels.quant.ref import (dequantize_int8_ref,  # noqa: E402
                                     quantize_int8_ref)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 24), st.sampled_from([16, 64, 256]),
       st.floats(0.01, 1e4), st.integers(0, 2**31 - 1))
def test_int8_roundtrip_error_bounded_by_half_scale(n_chunks, ce, scale,
                                                    seed):
    """|x - deq(quant(x))| <= scale_k / 2 for every element of chunk k."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.normal(size=n_chunks * ce) * scale)
                    .astype(np.float32))
    q, s = quantize_int8_ref(x, ce)
    back = dequantize_int8_ref(q, s, ce)
    err = np.abs(np.asarray(x) - np.asarray(back))
    bound = np.repeat(np.asarray(s), ce) * 0.5
    # tiny epsilon: the bound itself is computed in f32
    assert (err <= bound * (1 + 1e-6) + 1e-30).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 24), st.sampled_from([16, 64, 256]),
       st.integers(0, 2**31 - 1))
def test_scale_layout_tiles_chunk_domain_exactly_once(n_chunks, ce, seed):
    """One scale per chunk; chunk k's decode depends on scale k and
    nothing else (perturb one chunk -> only its scale and its span of
    the payload change)."""
    rng = np.random.default_rng(seed)
    n = n_chunks * ce
    x = np.asarray(rng.normal(size=n).astype(np.float32)) + 0.5
    q, s = quantize_int8_ref(jnp.asarray(x), ce)
    q, s = np.asarray(q), np.asarray(s)
    assert q.shape == (n,) and s.shape == (n_chunks,)
    spans = chunk_spans(n, ce)
    assert len(spans) == n_chunks
    covered = np.zeros(n, np.int32)
    for start, length in spans:
        covered[start:start + length] += 1
    assert (covered == 1).all()
    k = rng.integers(0, n_chunks)
    x2 = x.copy()
    start, length = spans[k]
    x2[start:start + length] *= 3.0
    q2, s2 = quantize_int8_ref(jnp.asarray(x2), ce)
    q2, s2 = np.asarray(q2), np.asarray(s2)
    unchanged = np.ones(n_chunks, bool)
    unchanged[k] = False
    assert (s2[unchanged] == s[unchanged]).all()
    mask = np.ones(n, bool)
    mask[start:start + length] = False
    assert (q2[mask] == q[mask]).all()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 5), st.integers(1, 17)),
                min_size=1, max_size=5),
       st.integers(1, 4), st.sampled_from([64, 256]))
def test_group_scale_table_matches_n_chunks(shapes, n_shards, chunk_bytes):
    """For every plan group, the per-chunk scale table of an int8-encoded
    (padded,) vector has exactly group.n_chunks entries and the spans
    tile [0, padded) — the wire layout and the chunk domain agree."""
    tree = {f"k{i}": jnp.zeros(s, jnp.float32)
            for i, s in enumerate(shapes)}
    plan = build_plan(tree, chunk_bytes=chunk_bytes, n_shards=n_shards)
    wire = WireFormat("int8")
    for g in plan.groups:
        x = jnp.ones((g.padded,), jnp.float32)
        q, s = wire.encode(x, g.chunk_elems)
        assert q.shape == (g.padded,)
        assert s.shape == (g.n_chunks,)
        assert g.n_chunks * g.chunk_elems == g.padded
        assert g.n_chunks == len(chunk_spans(g.padded, g.chunk_elems))
        # payload+scale byte accounting matches the layout
        assert wire.payload_bytes(g.padded, g.dtype, g.chunk_elems) == \
            g.padded * 1 + g.n_chunks * 4


def test_chunk_spans_rejects_misaligned():
    with pytest.raises(ValueError, match="chunk-aligned"):
        chunk_spans(100, 64)
